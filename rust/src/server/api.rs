//! NDIF HTTP API: routing, auth, request validation, metrics, and fleet
//! membership (self-registration with an L3 [`crate::coordinator`]).

use std::collections::HashMap;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{RecvTimeoutError, sync_channel};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::graph::plan::{self, PlanMode};
use crate::graph::plan_cache::PlanCache;
use crate::graph::serde as gserde;
use crate::json::{parse, Json};
use crate::models::ModelRunner;
use crate::scheduler::{CoTenancy, ModelService, StreamChunk, TenantCapExceeded, TenantDepths};
use crate::util::failpoint::{self, FailAction};

use super::admission::{AdmissionControl, Decision, RateLimit, ShedPolicy};
use super::http::{Chunk, Handler, HttpServer, Request, Response};
use super::state::{SessionStateStore, StateLimits};
use super::store::{Entry, ObjectStore};

/// Server configuration.
#[derive(Clone)]
pub struct NdifConfig {
    /// Bind address; use port 0 for ephemeral.
    pub addr: String,
    /// HTTP worker threads.
    pub workers: usize,
    /// Models to preload.
    pub models: Vec<String>,
    /// Artifacts directory.
    pub artifacts: PathBuf,
    /// Co-tenancy policy for every model service.
    pub cotenancy: CoTenancy,
    /// Per-model allowed auth tokens; models absent from the map are open.
    /// (Stands in for the paper's HuggingFace-gated model authorization.)
    pub auth: HashMap<String, Vec<String>>,
    /// Fleet coordinator address (`host:port`) to self-register with at
    /// startup; `None` runs standalone (the default).
    pub coordinator: Option<String>,
    /// Address advertised to the coordinator; defaults to the bound
    /// address (override when clients reach this replica differently).
    pub advertise: Option<String>,
    /// Interval between heartbeats pushed to the coordinator.
    pub heartbeat: Duration,
    /// One-way link latency (seconds) advertised to the coordinator — the
    /// replica's [`crate::netsim::NetSim`] profile, consumed by
    /// latency-aware routing.
    pub link_latency_s: f64,
    /// Budgets and TTL for server-side session state (named tensor
    /// variables held across traces — remote training loops).
    pub state_limits: StateLimits,
    /// Per-stream event buffer: how many step events may queue between the
    /// model worker and a slow chunked-response consumer before the worker
    /// blocks (the backpressure bound for `POST /v1/stream`).
    pub stream_buffer: usize,
    /// How long the model worker waits on a full stream buffer before
    /// declaring the consumer gone and aborting the decode.
    pub stream_send_timeout: Duration,
    /// Run submitted graphs through the admission compiler
    /// (`graph::opt`: DCE, constant folding, CSE, fusion) before
    /// execution. On by default; `--no-opt` (or `"optimize": false` in a
    /// config file) is the escape hatch for debugging and for measuring
    /// the optimizer itself (`benches/graphopt.rs`).
    pub optimize: bool,
    /// Cache compiled AOT execution plans (`graph::plan`) keyed by
    /// (model, structural hash): repeated-shape submissions skip
    /// validation, the optimizer, and scheduling prep, rebinding only
    /// constant payloads. On by default; `--no-plan-cache` (or
    /// `"plan_cache": false` in a config file) disables it — every
    /// request then takes the full validate + optimize path.
    pub plan_cache: bool,
    /// Plan-cache capacity in plans (LRU-evicted beyond it).
    pub plan_cache_cap: usize,
    /// Observability (latency histograms, request tracing, debug ring).
    /// On by default; `NNSCOPE_OBS=off` forces it off regardless
    /// (`benches/obs.rs` gates the instrumented-vs-off overhead).
    pub obs: bool,
    /// Capacity of the finished-request ring served at
    /// `GET /v1/debug/requests`.
    pub trace_ring: usize,
    /// Capacity of the finished-profile ring served at
    /// `GET /v1/debug/profile/<id>` (trace-event JSON per profiled
    /// request).
    pub profile_ring: usize,
    /// Deep-profile 1 in N unsolicited requests (0 = only requests that
    /// ask, via the `x-nnscope-profile` header or `"profile": true`).
    pub profile_sample_n: usize,
    /// Durable-results directory: when set, completed results are
    /// journaled to `<data_dir>/store.journal` and survive a crash —
    /// a restarted replica replays the journal and serves them again
    /// (exactly-once pickup still holds: delivery evicts durably too).
    pub data_dir: Option<PathBuf>,
    /// Per-tenant token-bucket rate limit (keyed by auth token; anonymous
    /// traffic pools). `None` = unlimited (the default).
    pub rate_limit: Option<RateLimit>,
    /// Per-tenant in-flight queue-depth cap across this replica's model
    /// services; breaching it is the tenant's own backpressure (429).
    pub tenant_queue_cap: usize,
    /// Graceful load shedding at total-queue-depth watermarks (anonymous
    /// traffic shed first). Disabled by default.
    pub shed: ShedPolicy,
}

impl NdifConfig {
    pub fn local(models: &[&str]) -> NdifConfig {
        NdifConfig {
            addr: "127.0.0.1:0".into(),
            workers: 8,
            models: models.iter().map(|s| s.to_string()).collect(),
            artifacts: crate::models::artifacts_dir(),
            cotenancy: CoTenancy::Sequential,
            auth: HashMap::new(),
            coordinator: None,
            advertise: None,
            heartbeat: Duration::from_millis(250),
            link_latency_s: 0.0,
            state_limits: StateLimits::default(),
            stream_buffer: 32,
            stream_send_timeout: Duration::from_secs(10),
            optimize: true,
            plan_cache: true,
            plan_cache_cap: 256,
            obs: true,
            trace_ring: 256,
            profile_ring: 64,
            profile_sample_n: 0,
            data_dir: None,
            rate_limit: None,
            tenant_queue_cap: usize::MAX,
            shed: ShedPolicy::disabled(),
        }
    }
}

/// Fault-tolerance counters surfaced under `_faults` in `/v1/metrics`.
#[derive(Default)]
struct FaultStats {
    /// Requests rejected 429 (rate limit or tenant queue cap).
    throttled: AtomicU64,
    /// Requests shed 503 at the load watermarks.
    shed: AtomicU64,
    /// Completed results recovered from the journal at startup.
    journal_replayed: AtomicU64,
    /// Torn/corrupt bytes truncated from the journal tail at startup.
    journal_truncated_bytes: AtomicU64,
}

struct ServerState {
    services: HashMap<String, ModelService>,
    store: Arc<ObjectStore>,
    session_state: Arc<SessionStateStore>,
    next_id: AtomicU64,
    auth: HashMap<String, Vec<String>>,
    /// Stream backpressure knobs (see [`NdifConfig`]).
    stream_buffer: usize,
    stream_send_timeout: Duration,
    /// Admission-compiler toggle (see [`NdifConfig::optimize`]).
    optimize: bool,
    /// AOT plan cache (`None` = `--no-plan-cache`: full validate +
    /// optimize on every admission).
    plans: Option<Arc<PlanCache>>,
    /// Observability hub: per-model/per-endpoint histograms, opt-pass
    /// counters, and the finished-request debug ring.
    obs: Arc<crate::obs::Obs>,
    /// Deep-profile 1 in N unsolicited requests (0 = opt-in only).
    profile_sample_n: usize,
    /// Admitted-request counter driving the 1-in-N profile sampling.
    profile_counter: AtomicU64,
    /// Per-tenant token buckets (`None` = unlimited).
    admission: Option<AdmissionControl>,
    /// Load-shed watermarks over the summed queue depth.
    shed: ShedPolicy,
    /// Fault-tolerance counters (throttles, sheds, journal recovery).
    faults: FaultStats,
    /// Set during shutdown/kill: in-flight chunked responses abort (drop
    /// the connection without the terminator) instead of outliving the
    /// server — this is what lets a mid-stream replica death surface as a
    /// truncated stream at the coordinator.
    draining: AtomicBool,
}

impl ServerState {
    fn authorize(&self, model: &str, token: Option<&str>) -> bool {
        match self.auth.get(model) {
            None => true,
            Some(allowed) => token.map(|t| allowed.iter().any(|a| a == t)).unwrap_or(false),
        }
    }

    /// Summed queue depth across all model services — the load-shed
    /// signal.
    fn total_queue_depth(&self) -> usize {
        self.services.values().map(|s| s.load().queue_depth).sum()
    }
}

/// Fleet membership of a replica that self-registered with a coordinator.
struct FleetMembership {
    coordinator: SocketAddr,
    replica_id: String,
    stop: Arc<AtomicBool>,
    heartbeat_thread: Option<std::thread::JoinHandle<()>>,
}

/// A running NDIF server.
pub struct NdifServer {
    http: HttpServer,
    state: Arc<ServerState>,
    fleet: Option<FleetMembership>,
}

impl NdifServer {
    /// Preload the configured models and start serving. With
    /// [`NdifConfig::coordinator`] set, also register this deployment as a
    /// fleet replica and start pushing heartbeats.
    pub fn start(cfg: NdifConfig) -> Result<NdifServer> {
        // durable mode: open + replay the journal before serving, so
        // results completed by a previous incarnation are deliverable
        // again, and resume the id counter past every replayed id
        let faults = FaultStats::default();
        let (store, next_id) = match &cfg.data_dir {
            Some(dir) => {
                let (store, report) =
                    ObjectStore::with_journal(ObjectStore::DEFAULT_TTL, &dir.join("store.journal"))
                        .context("open durable result journal")?;
                faults
                    .journal_replayed
                    .store(report.entries.len() as u64, Ordering::Relaxed);
                faults
                    .journal_truncated_bytes
                    .store(report.truncated_bytes as u64, Ordering::Relaxed);
                if report.truncated_bytes > 0 {
                    eprintln!(
                        "nnscope: journal replay truncated {} torn byte(s) at the tail",
                        report.truncated_bytes
                    );
                }
                let next = store.max_id_suffix("r-").map(|n| n + 1).unwrap_or(1);
                (Arc::new(store), next)
            }
            None => (Arc::new(ObjectStore::new()), 1),
        };
        let session_state = Arc::new(SessionStateStore::new(cfg.state_limits));
        let obs = Arc::new(crate::obs::Obs::new(
            cfg.obs,
            &cfg.models,
            cfg.trace_ring,
            cfg.profile_ring,
        ));
        // one tenant-depth tracker spans every model service, so a
        // tenant's in-flight cap can't be dodged by spreading over models
        let tenants = Arc::new(TenantDepths::new(cfg.tenant_queue_cap));
        let mut services = HashMap::new();
        for name in &cfg.models {
            let runner = Arc::new(
                ModelRunner::load(&cfg.artifacts, name)
                    .with_context(|| format!("preload model {name}"))?,
            );
            services.insert(
                name.clone(),
                ModelService::start_with_tenants(
                    runner,
                    Arc::clone(&store),
                    Arc::clone(&session_state),
                    cfg.cotenancy,
                    obs.service_obs(name),
                    Arc::clone(&tenants),
                ),
            );
        }
        let state = Arc::new(ServerState {
            services,
            store,
            session_state,
            next_id: AtomicU64::new(next_id),
            auth: cfg.auth.clone(),
            stream_buffer: cfg.stream_buffer.max(1),
            stream_send_timeout: cfg.stream_send_timeout,
            optimize: cfg.optimize,
            plans: cfg.plan_cache.then(|| Arc::new(PlanCache::new(cfg.plan_cache_cap))),
            obs,
            profile_sample_n: cfg.profile_sample_n,
            profile_counter: AtomicU64::new(0),
            admission: cfg.rate_limit.map(AdmissionControl::new),
            shed: cfg.shed,
            faults,
            draining: AtomicBool::new(false),
        });
        let s2 = Arc::clone(&state);
        let handler: Handler = Arc::new(move |req| route(&s2, req));
        let http = HttpServer::bind(&cfg.addr, cfg.workers, handler)?;
        let mut server = NdifServer { http, state, fleet: None };
        if let Some(coordinator) = &cfg.coordinator {
            server.join_fleet(&cfg, coordinator)?;
        }
        Ok(server)
    }

    /// Register with the coordinator and spawn the heartbeat pusher.
    fn join_fleet(&mut self, cfg: &NdifConfig, coordinator: &str) -> Result<()> {
        use crate::coordinator::api as fleet;
        let coordinator: SocketAddr = coordinator
            .parse()
            .with_context(|| format!("coordinator address '{coordinator}'"))?;
        let advertise: SocketAddr = match &cfg.advertise {
            Some(a) => a.parse().with_context(|| format!("advertise address '{a}'"))?,
            None => self.addr(),
        };
        if advertise.ip().is_unspecified() {
            anyhow::bail!(
                "replica bound to wildcard address {advertise}: the coordinator cannot \
                 route to it — set NdifConfig.advertise (--advertise) to a reachable address"
            );
        }
        let models: Vec<String> = cfg.models.clone();
        let latency_s = cfg.link_latency_s;
        let replica_id = fleet::register_replica(coordinator, advertise, &models, latency_s, None)
            .context("register with fleet coordinator")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let state2 = Arc::clone(&self.state);
        let id2 = replica_id.clone();
        let interval = cfg.heartbeat;
        let heartbeat_thread = std::thread::Builder::new()
            .name("ndif-heartbeat".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    // chaos hooks: Skip drops this beat on the floor (the
                    // coordinator must ride it out via hysteresis), Delay
                    // simulates a stalled replica
                    match failpoint::hit("replica.heartbeat") {
                        Some(FailAction::Skip) => continue,
                        Some(FailAction::Delay(d)) => std::thread::sleep(d),
                        _ => {}
                    }
                    let mut agg = crate::scheduler::LoadSnapshot::default();
                    for s in state2.services.values() {
                        let l = s.load();
                        agg.queue_depth += l.queue_depth;
                        agg.completed += l.completed;
                        agg.failed += l.failed;
                    }
                    // observed end-to-end p95 (ms) across all models, so
                    // the coordinator's routers can weigh real latency,
                    // not just queue depth
                    let p95_ms = state2.obs.merged_e2e().percentile(0.95) * 1e3;
                    // 404 = the coordinator restarted and forgot us: reclaim
                    // our id; transport errors are left for the next beat
                    if let Ok(404) = fleet::send_heartbeat(coordinator, &id2, &agg, p95_ms) {
                        let _ = fleet::register_replica(
                            coordinator,
                            advertise,
                            &models,
                            latency_s,
                            Some(&id2),
                        );
                    }
                }
            })?;
        self.fleet = Some(FleetMembership {
            coordinator,
            replica_id,
            stop,
            heartbeat_thread: Some(heartbeat_thread),
        });
        Ok(())
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr()
    }

    /// Fleet replica id, when registered with a coordinator.
    pub fn replica_id(&self) -> Option<&str> {
        self.fleet.as_ref().map(|f| f.replica_id.as_str())
    }

    /// Metrics snapshot for a model (enqueued, completed, failed, merged).
    pub fn metrics(&self, model: &str) -> Option<(u64, u64, u64, u64)> {
        self.state.services.get(model).map(|s| {
            (
                s.metrics.enqueued.load(Ordering::Relaxed),
                s.metrics.completed.load(Ordering::Relaxed),
                s.metrics.failed.load(Ordering::Relaxed),
                s.metrics.merged_batches.load(Ordering::Relaxed),
            )
        })
    }

    /// Drop every cached AOT plan compiled for `model` — the invalidation
    /// contract for a model reload/swap: a stale plan compiled against
    /// the old weights' manifest must never execute against the new ones.
    /// Keyed eviction, not TTL: returns how many plans were dropped.
    /// (Model hot-swap itself is not implemented yet; the path that will
    /// do it MUST call this first.)
    pub fn invalidate_plans(&self, model: &str) -> usize {
        self.state
            .plans
            .as_ref()
            .map(|c| c.invalidate_model(model))
            .unwrap_or(0)
    }

    /// Graceful shutdown: stop heartbeating, say goodbye to the
    /// coordinator, then stop serving.
    pub fn shutdown(&mut self) {
        self.state.draining.store(true, Ordering::SeqCst);
        if let Some(mut f) = self.fleet.take() {
            f.stop.store(true, Ordering::SeqCst);
            if let Some(t) = f.heartbeat_thread.take() {
                let _ = t.join();
            }
            let _ = crate::coordinator::api::deregister_replica(f.coordinator, &f.replica_id);
        }
        // flush any fsync-batched journal tail: a graceful shutdown loses
        // nothing (a crash may lose up to the last fsync batch)
        self.state.store.sync_journal();
        self.http.shutdown();
    }

    /// Simulate a crash (fleet tests): stop serving and heartbeating
    /// WITHOUT deregistering, so the coordinator must detect the death via
    /// heartbeat age / transport failures. In-flight streams are cut
    /// without their terminator, exactly like a process death.
    pub fn kill(&mut self) {
        self.state.draining.store(true, Ordering::SeqCst);
        if let Some(mut f) = self.fleet.take() {
            f.stop.store(true, Ordering::SeqCst);
            if let Some(t) = f.heartbeat_thread.take() {
                let _ = t.join();
            }
        }
        self.http.shutdown();
    }
}

impl Drop for NdifServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn route(state: &Arc<ServerState>, req: Request) -> Response {
    // per-endpoint request/error counters + latency histograms ride
    // every call to an instrumented endpoint
    let endpoint = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/trace") => Some("trace"),
        ("POST", "/v1/session") => Some("session"),
        ("POST", "/v1/stream") => Some("stream"),
        ("GET", p) if p.starts_with("/v1/result/") => Some("result"),
        _ => None,
    };
    let t0 = Instant::now();
    let resp = route_inner(state, req);
    if let Some(e) = endpoint {
        state.obs.record_endpoint(e, t0.elapsed(), resp.status < 400);
    }
    resp
}

/// Admission control for work-submitting endpoints, checked before any
/// parsing: load shed at the queue-depth watermarks (503, retryable —
/// any replica may be healthier), then the tenant's token bucket (429,
/// retryable with `Retry-After` — the tenant's own backpressure, which a
/// coordinator must NOT fail over on). The error envelope carries
/// `retry_after_ms` because the in-repo client surfaces only the body.
fn admission_gate(state: &Arc<ServerState>, req: &Request) -> Option<Response> {
    let tenant = req.header("x-ndif-auth");
    if state.shed.shed(state.total_queue_depth(), tenant.is_none()) {
        state.faults.shed.fetch_add(1, Ordering::Relaxed);
        return Some(
            Response::json(
                503,
                "{\"error\":\"overloaded, load shed\",\"retryable\":true,\"retry_after_ms\":1000}"
                    .into(),
            )
            .with_header("Retry-After", "1"),
        );
    }
    let adm = state.admission.as_ref()?;
    match adm.check(tenant.unwrap_or("anon")) {
        Decision::Admit => None,
        Decision::Throttle { retry_after } => {
            state.faults.throttled.fetch_add(1, Ordering::Relaxed);
            Some(throttle_response(retry_after))
        }
    }
}

/// 429 with the advertised wait in both forms: `Retry-After` header
/// (whole seconds, ceiling, min 1) and `retry_after_ms` in the envelope.
/// Shared with the coordinator front, which applies the same contract.
pub(crate) fn throttle_response(retry_after: Duration) -> Response {
    let ms = retry_after.as_millis().max(1) as u64;
    let secs = ms.div_ceil(1000).max(1);
    Response::json(
        429,
        format!("{{\"error\":\"rate limited\",\"retryable\":true,\"retry_after_ms\":{ms}}}"),
    )
    .with_header("Retry-After", &secs.to_string())
}

fn route_inner(state: &Arc<ServerState>, req: Request) -> Response {
    if matches!(
        (req.method.as_str(), req.path.as_str()),
        ("POST", "/v1/trace") | ("POST", "/v1/session") | ("POST", "/v1/stream")
    ) {
        if let Some(resp) = admission_gate(state, &req) {
            return resp;
        }
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Response::text(200, "ok"),
        ("GET", "/v1/models") => models_endpoint(state),
        ("POST", "/v1/trace") => trace_endpoint(state, &req),
        ("POST", "/v1/session") => session_endpoint(state, &req),
        ("POST", "/v1/stream") => stream_endpoint(state, &req),
        ("GET", "/v1/debug/requests") => debug_requests_endpoint(state),
        ("GET", "/v1/debug/hotops") => debug_hotops_endpoint(state),
        ("GET", path) if path.starts_with("/v1/debug/profile/") => {
            debug_profile_endpoint(state, &path["/v1/debug/profile/".len()..])
        }
        ("GET", path) if path == "/v1/metrics" || path.starts_with("/v1/metrics?") => {
            metrics_endpoint(state, path)
        }
        ("GET", path) if path.starts_with("/v1/result/") => result_endpoint(state, path),
        ("GET", path) if path.starts_with("/v1/session/") => {
            session_info_endpoint(state, &req, &path["/v1/session/".len()..])
        }
        ("DELETE", path) if path.starts_with("/v1/session/") => {
            session_drop_endpoint(state, &req, &path["/v1/session/".len()..])
        }
        _ => Response::not_found(),
    }
}

fn models_endpoint(state: &Arc<ServerState>) -> Response {
    let models: Vec<Json> = state
        .services
        .values()
        .map(|s| {
            let m = &s.runner.manifest;
            Json::obj(vec![
                ("name", Json::from(m.name.as_str())),
                ("params", Json::from(m.param_count)),
                ("n_layers", Json::from(m.n_layers)),
                ("seq", Json::from(m.seq)),
                ("batches", Json::from(m.batches.clone())),
                ("simulates", Json::from(m.simulates.as_str())),
                ("grad", Json::from(m.grad)),
                ("tp", Json::from(m.tp.clone())),
            ])
        })
        .collect();
    Response::json(200, Json::obj(vec![("models", Json::Array(models))]).to_string())
}

fn submit_graph(state: &Arc<ServerState>, req: &Request, body: &Json) -> Result<String, Response> {
    let graph = gserde::from_json(body).map_err(|e| Response::bad_request(&e.to_string()))?;
    let profile = wants_profile(state, req, body);
    submit_parsed_graph(state, req, graph, "trace", profile)
}

/// Should this request's execution be deep-profiled? Armed explicitly by
/// the `x-nnscope-profile` header or a top-level `"profile": true` body
/// key (both fleet-transparent — the coordinator forwards headers and
/// bodies verbatim), or by the `--profile-sample-n` 1-in-N unsolicited
/// sampler. Always false with observability off: the profiler rides the
/// obs plumbing (trace ids, the scheduler's ServiceObs).
fn wants_profile(state: &Arc<ServerState>, req: &Request, body: &Json) -> bool {
    if !state.obs.enabled() {
        return false;
    }
    if req
        .header(crate::obs::PROFILE_HEADER)
        .is_some_and(|v| v != "0")
        || body.get("profile").as_bool() == Some(true)
    {
        return true;
    }
    let n = state.profile_sample_n as u64;
    n > 0 && state.profile_counter.fetch_add(1, Ordering::Relaxed) % n == 0
}

/// Open a request trace for an admitted request: reuse the id from the
/// `x-nnscope-trace` header (client- or coordinator-minted) or mint one.
/// `None` when observability is off.
fn open_trace(
    state: &Arc<ServerState>,
    req: &Request,
    endpoint: &'static str,
    model: &str,
) -> Option<crate::obs::ReqTrace> {
    if !state.obs.enabled() {
        return None;
    }
    let tid = req
        .header(crate::obs::TRACE_HEADER)
        .map(str::to_string)
        .unwrap_or_else(crate::obs::mint_trace_id);
    Some(crate::obs::ReqTrace::new(tid, endpoint, model))
}

fn submit_parsed_graph(
    state: &Arc<ServerState>,
    req: &Request,
    graph: crate::graph::InterventionGraph,
    endpoint: &'static str,
    profile: bool,
) -> Result<String, Response> {
    let Some(service) = state.services.get(&graph.model) else {
        return Err(Response::json(
            404,
            format!("{{\"error\":\"model '{}' not hosted\"}}", graph.model),
        ));
    };
    if !state.authorize(&graph.model, req.header("x-ndif-auth")) {
        return Err(Response::json(
            401,
            "{\"error\":\"not authorized for this model\"}".into(),
        ));
    }
    // state dataflow needs the ordered session pipeline, not a lone trace
    if graph.uses_state() {
        return Err(Response::bad_request(
            "graph uses session-state ops (load_state/store_state); submit it via POST /v1/session",
        ));
    }
    let model = graph.model.clone();
    let mut trace = open_trace(state, req, endpoint, &model);
    let fseq = service.runner.manifest.forward_sequence();
    let prepared = match &state.plans {
        // plan-cache admission: a structural hit skips validation AND the
        // optimizer (their verdicts are structural — see `graph::plan`),
        // paying only the constant rebind; a miss takes the full path
        // once and caches the compiled plan for every same-shape follow-up
        Some(cache) => {
            let key = plan::structural_key(&graph, PlanMode::Trace, state.optimize);
            match cache.get(&model, key) {
                Some(p) => {
                    if let Some(m) = state.obs.model(&model) {
                        m.record_plan(true);
                    }
                    crate::obs::timed(&mut trace, "plan_bind", || p.bind(&graph))
                        .map_err(|e| Response::bad_request(&e.to_string()))?
                }
                None => {
                    if let Some(m) = state.obs.model(&model) {
                        m.record_plan(false);
                    }
                    if let Err(e) = crate::obs::timed(&mut trace, "validate", || {
                        crate::graph::validate::validate(&graph, &fseq)
                    }) {
                        return Err(Response::bad_request(&e.to_string()));
                    }
                    // parametric admission compile: same pipeline as the
                    // legacy path, but constants stay structural so the
                    // plan is reusable. A folding failure — e.g. `mean`
                    // over an empty constant subtree — is a guaranteed
                    // execution failure, so it is a clean 400 here, and
                    // the failed compile is never cached (both-fail
                    // parity: resubmitting the bad graph fails again).
                    let p = crate::obs::timed(&mut trace, "opt", || {
                        plan::compile(&graph, &fseq, PlanMode::Trace, state.optimize).map(Arc::new)
                    })
                    .map_err(|e| Response::bad_request(&e.to_string()))?;
                    cache.insert(&model, key, Arc::clone(&p));
                    if let (Some(report), Some(m)) = (p.report(), state.obs.model(&model)) {
                        m.record_opt(&report);
                    }
                    crate::obs::timed(&mut trace, "plan_bind", || p.bind(&graph))
                        .map_err(|e| Response::bad_request(&e.to_string()))?
                }
            }
        }
        None => {
            // early validation against the manifest so bad graphs fail at
            // submit
            if let Err(e) = crate::obs::timed(&mut trace, "validate", || {
                crate::graph::validate::validate(&graph, &fseq)
            }) {
                return Err(Response::bad_request(&e.to_string()));
            }
            // admission compile (between validation and execution): DCE,
            // constant folding, CSE, fusion. A folding failure — e.g.
            // `mean` over an empty constant subtree — is a guaranteed
            // execution failure, so it is a clean 400 here rather than a
            // mid-forward 500.
            let prepared = crate::obs::timed(&mut trace, "opt", || {
                crate::graph::opt::prepare(graph, &fseq, state.optimize)
            })
            .map_err(|e| Response::bad_request(&e.to_string()))?;
            if let (Some(report), Some(m)) = (prepared.report.as_ref(), state.obs.model(&model)) {
                m.record_opt(report);
            }
            prepared
        }
    };
    let id = format!("r-{}", state.next_id.fetch_add(1, Ordering::Relaxed));
    state.store.put_pending(&id);
    let opts = crate::scheduler::SubmitOpts::new()
        .traced(trace)
        .tenant(req.header("x-ndif-auth"))
        .profiled(profile);
    service
        .submit_trace(id.clone(), prepared, opts)
        .map_err(|e| submit_error_response(state, e))?;
    Ok(id)
}

/// Map a scheduler submit error: a tenant at its queue-depth cap is a
/// 429 (the tenant's own backpressure; a coordinator must not fail over
/// on it), anything else — worker death — is a retryable 503.
fn submit_error_response(state: &Arc<ServerState>, e: anyhow::Error) -> Response {
    if e.downcast_ref::<TenantCapExceeded>().is_some() {
        state.faults.throttled.fetch_add(1, Ordering::Relaxed);
        return Response::json(
            429,
            format!(
                "{{\"error\":{},\"retryable\":true,\"retry_after_ms\":250}}",
                Json::from(e.to_string())
            ),
        )
        .with_header("Retry-After", "1");
    }
    Response::json(
        503,
        format!("{{\"error\":{},\"retryable\":true}}", Json::from(e.to_string())),
    )
}

fn trace_endpoint(state: &Arc<ServerState>, req: &Request) -> Response {
    let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
        parse(s).map_err(|e| e.to_string())
    }) {
        Ok(j) => j,
        Err(e) => return Response::bad_request(&e),
    };
    match submit_graph(state, req, &body) {
        Ok(id) => Response::json(202, Json::obj(vec![("id", Json::from(id))]).to_string()),
        Err(resp) => resp,
    }
}

/// A Session: multiple traces executed in order within one request
/// (§B.1 "Remote Execution and Session"). Sent as
/// `{"traces": [graph, graph, ...]}` plus an optional `"session"` name;
/// FIFO queueing per model preserves order, and the response bundles all
/// results, eliminating per-trace round trips.
///
/// Two execution paths:
/// * **stateless** (no state ops, no `"session"` field) — each trace is an
///   independent submit; parallel co-tenancy may merge them;
/// * **stateful** — the bundle is validated as a whole (state keys thread
///   across traces) and runs strictly in order on the model's worker,
///   loads/stores resolving against server-side session state. With a
///   client-named `"session"` the state persists for follow-up requests
///   (until `DELETE /v1/session/<id>` or TTL expiry); anonymous sessions
///   drop their state when the response is sent.
fn session_endpoint(state: &Arc<ServerState>, req: &Request) -> Response {
    let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
        parse(s).map_err(|e| e.to_string())
    }) {
        Ok(j) => j,
        Err(e) => return Response::bad_request(&e),
    };
    let Some(traces) = body.get("traces").as_array() else {
        return Response::bad_request("session missing traces");
    };
    let mut graphs = Vec::with_capacity(traces.len());
    for t in traces {
        match gserde::from_json(t) {
            Ok(g) => graphs.push(g),
            Err(e) => return Response::bad_request(&e.to_string()),
        }
    }
    let named = body.get("session").as_str();
    let profile = wants_profile(state, req, &body);
    if named.is_some() || graphs.iter().any(|g| g.uses_state()) {
        stateful_session(state, req, graphs, named, profile)
    } else {
        stateless_session(state, req, graphs, profile)
    }
}

/// The legacy bundling path: independent per-trace submits, results
/// gathered in order.
fn stateless_session(
    state: &Arc<ServerState>,
    req: &Request,
    graphs: Vec<crate::graph::InterventionGraph>,
    profile: bool,
) -> Response {
    let mut ids = Vec::with_capacity(graphs.len());
    for g in graphs {
        match submit_parsed_graph(state, req, g, "session", profile) {
            Ok(id) => ids.push(id),
            Err(resp) => return resp,
        }
    }
    // gather all results (bounded wait per trace)
    let mut results = Vec::with_capacity(ids.len());
    for id in &ids {
        match state.store.wait_outcome(id, Duration::from_secs(300)) {
            Some(Ok(json)) => match parse(&json) {
                Ok(j) => results.push(j),
                Err(e) => return Response::json(500, format!("{{\"error\":\"{e}\"}}")),
            },
            Some(Err(e)) => {
                return Response::json(500, format!("{{\"error\":{}}}", Json::from(e)));
            }
            None => return Response::json(500, "{\"error\":\"session timeout\"}".into()),
        }
    }
    Response::json(
        200,
        Json::obj(vec![("results", Json::Array(results))]).to_string(),
    )
}

/// The stateful path: whole-bundle validation, ordered execution with
/// server-side state threading, one bundled result.
fn stateful_session(
    state: &Arc<ServerState>,
    req: &Request,
    graphs: Vec<crate::graph::InterventionGraph>,
    named: Option<&str>,
    profile: bool,
) -> Response {
    let Some(model) = graphs.first().map(|g| g.model.clone()) else {
        return Response::bad_request("stateful session has no traces");
    };
    if graphs.iter().any(|g| g.model != model) {
        return Response::bad_request(
            "stateful session traces must target one model (state lives with its service)",
        );
    }
    let Some(service) = state.services.get(&model) else {
        return Response::json(404, format!("{{\"error\":\"model '{model}' not hosted\"}}"));
    };
    if !state.authorize(&model, req.header("x-ndif-auth")) {
        return Response::json(401, "{\"error\":\"not authorized for this model\"}".into());
    }
    // "es-" is the anonymous-session namespace: a client-named session in
    // it could collide with a generated id, exposing or destroying state
    if let Some(s) = named {
        if s.starts_with("es-") {
            return Response::bad_request(
                "session ids beginning with 'es-' are reserved for anonymous sessions",
            );
        }
    }
    let (session, persist) = match named {
        Some(s) => (s.to_string(), true),
        None => (format!("es-{}", state.next_id.fetch_add(1, Ordering::Relaxed)), false),
    };
    // a reused session id must stay on the model its state is bound to
    if let Some(bound) = state.session_state.model_of(&session) {
        if bound != model {
            return Response::bad_request(&format!(
                "session '{session}' is bound to model '{bound}', not '{model}'"
            ));
        }
    }
    let mut trace = open_trace(state, req, "session", &model);
    // whole-bundle validation: keys stored by trace i are loadable from
    // trace i+1 on; a persistent session also starts with its live keys
    let initial = state.session_state.keys(&session).unwrap_or_default();
    let fseq = service.runner.manifest.forward_sequence();
    if let Err(e) = crate::obs::timed(&mut trace, "validate", || {
        crate::graph::validate::validate_session(&graphs, &fseq, &initial)
    }) {
        return Response::bad_request(&e.to_string());
    }
    // admission compile per trace (state ops are roots, so the compiler
    // never folds across LoadState or drops a StoreState). With the plan
    // cache on, each trace gets-or-compiles a Session-mode plan: the
    // bundle is still validated as a whole above on EVERY request —
    // state-key availability is per-request state, not structure — but
    // cache hits skip the optimizer passes and scheduling prep.
    let prepared = {
        let optimize = state.optimize;
        let plans = state.plans.as_deref();
        let obs_model = state.obs.model(&model).cloned();
        let r = crate::obs::timed(&mut trace, "opt", || {
            let mut acc = Vec::with_capacity(graphs.len());
            for (i, g) in graphs.into_iter().enumerate() {
                let p = match plans {
                    Some(cache) => {
                        let key = plan::structural_key(&g, PlanMode::Session, optimize);
                        let plan = match cache.get(&model, key) {
                            Some(p) => {
                                if let Some(m) = &obs_model {
                                    m.record_plan(true);
                                }
                                p
                            }
                            None => match plan::compile(&g, &fseq, PlanMode::Session, optimize) {
                                Ok(p) => {
                                    let p = Arc::new(p);
                                    cache.insert(&model, key, Arc::clone(&p));
                                    if let Some(m) = &obs_model {
                                        m.record_plan(false);
                                        if let Some(report) = p.report() {
                                            m.record_opt(&report);
                                        }
                                    }
                                    p
                                }
                                Err(e) => return Err(format!("session trace {i}: {e}")),
                            },
                        };
                        match plan.bind(&g) {
                            Ok(p) => p,
                            Err(e) => return Err(format!("session trace {i}: {e}")),
                        }
                    }
                    None => match crate::graph::opt::prepare(g, &fseq, optimize) {
                        Ok(p) => p,
                        Err(e) => return Err(format!("session trace {i}: {e}")),
                    },
                };
                acc.push(p);
            }
            Ok(acc)
        });
        match r {
            Ok(p) => p,
            Err(e) => return Response::bad_request(&e),
        }
    };
    if state.plans.is_none() {
        if let Some(m) = state.obs.model(&model) {
            for p in &prepared {
                if let Some(report) = p.report.as_ref() {
                    m.record_opt(report);
                }
            }
        }
    }
    let id = format!("r-{}", state.next_id.fetch_add(1, Ordering::Relaxed));
    let opts = crate::scheduler::SubmitOpts::new()
        .traced(trace)
        .tenant(req.header("x-ndif-auth"))
        .profiled(profile);
    if let Err(e) = service.submit_session(id.clone(), session, persist, prepared, opts) {
        return submit_error_response(state, e);
    }
    match state.store.wait_outcome(&id, Duration::from_secs(300)) {
        Some(Ok(json)) => Response::json(200, json),
        Some(Err(e)) => Response::json(500, format!("{{\"error\":{}}}", Json::from(e))),
        None => Response::json(500, "{\"error\":\"session timeout\"}".into()),
    }
}

/// Upper bound on one streaming request's decode length (a runaway-loop
/// backstop, far above any interactive use).
const MAX_STREAM_STEPS: usize = 100_000;

/// Fail fast at submit on constraints the decode loop would otherwise
/// only hit mid-stream. All three inputs are hashed into the structural
/// plan key, so a plan-cache hit implies the guard passed when the plan
/// was first compiled.
fn stream_shape_guard(graph: &crate::graph::InterventionGraph, seq: usize) -> Option<Response> {
    if graph.batch != 1 {
        return Some(Response::bad_request(&format!(
            "streaming generation is single-sequence, got batch {}",
            graph.batch
        )));
    }
    if graph.tokens.len() != seq {
        return Some(Response::bad_request(&format!(
            "streaming prompt must be [1, {seq}] tokens, got {}",
            graph.tokens.len()
        )));
    }
    if graph.shards > 1 {
        return Some(Response::bad_request("streaming decode is unsharded"));
    }
    None
}

/// Streaming generation with per-step interventions (`POST /v1/stream`).
///
/// Request body: an intervention-graph JSON (as for `/v1/trace`) plus a
/// top-level `"steps": N`. The graph re-executes at every decode step;
/// `step_hook` (and `save`) values are emitted per step. Response:
/// `Transfer-Encoding: chunked`, one NDJSON line per event —
/// `{"event":"step", "step":i, "token":t, "score":s, "values":{...}}` per
/// decode step, terminated by exactly one
/// `{"event":"done", "tokens":[..], "scores":[..]}` or
/// `{"event":"error", "error":..., "retryable":false}`. A stream that ends
/// WITHOUT a terminal event (connection cut before the chunked terminator)
/// means the server died mid-stream; the coordinator converts that into a
/// retryable tail event for its clients.
///
/// Backpressure: events flow through a bounded channel sized
/// [`NdifConfig::stream_buffer`]; a consumer that stops draining for
/// longer than [`NdifConfig::stream_send_timeout`] aborts the decode, so
/// slow readers cannot pin the model worker.
fn stream_endpoint(state: &Arc<ServerState>, req: &Request) -> Response {
    let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
        parse(s).map_err(|e| e.to_string())
    }) {
        Ok(j) => j,
        Err(e) => return Response::bad_request(&e),
    };
    let Some(steps) = body.get("steps").as_usize() else {
        return Response::bad_request("stream request missing steps");
    };
    if steps == 0 || steps > MAX_STREAM_STEPS {
        return Response::bad_request(&format!(
            "steps must be in 1..={MAX_STREAM_STEPS}, got {steps}"
        ));
    }
    let graph = match gserde::from_json(&body) {
        Ok(g) => g,
        Err(e) => return Response::bad_request(&e.to_string()),
    };
    let model = graph.model.clone();
    let Some(service) = state.services.get(&model) else {
        return Response::json(404, format!("{{\"error\":\"model '{model}' not hosted\"}}"));
    };
    if !state.authorize(&model, req.header("x-ndif-auth")) {
        return Response::json(401, "{\"error\":\"not authorized for this model\"}".into());
    }
    let mut trace = open_trace(state, req, "stream", &model);
    let fseq = service.runner.manifest.forward_sequence();
    let seq = service.runner.manifest.seq;
    let prepared = match &state.plans {
        // plan-cache admission (Stream mode keys are disjoint from Trace
        // keys — the rule sets differ): a structural hit skips stream
        // validation, the shape guards (batch, prompt length, and shards
        // are all part of the key), and the optimizer
        Some(cache) => {
            let key = plan::structural_key(&graph, PlanMode::Stream, state.optimize);
            match cache.get(&model, key) {
                Some(p) => {
                    if let Some(m) = state.obs.model(&model) {
                        m.record_plan(true);
                    }
                    match crate::obs::timed(&mut trace, "plan_bind", || p.bind(&graph)) {
                        Ok(p) => p,
                        Err(e) => return Response::bad_request(&e.to_string()),
                    }
                }
                None => {
                    if let Some(m) = state.obs.model(&model) {
                        m.record_plan(false);
                    }
                    if let Err(e) = crate::obs::timed(&mut trace, "validate", || {
                        crate::graph::validate::validate_stream(&graph, &fseq)
                    }) {
                        return Response::bad_request(&e.to_string());
                    }
                    if let Some(resp) = stream_shape_guard(&graph, seq) {
                        return resp;
                    }
                    let p = match crate::obs::timed(&mut trace, "opt", || {
                        plan::compile(&graph, &fseq, PlanMode::Stream, state.optimize)
                            .map(Arc::new)
                    }) {
                        Ok(p) => p,
                        Err(e) => return Response::bad_request(&e.to_string()),
                    };
                    cache.insert(&model, key, Arc::clone(&p));
                    if let (Some(report), Some(m)) = (p.report(), state.obs.model(&model)) {
                        m.record_opt(&report);
                    }
                    match crate::obs::timed(&mut trace, "plan_bind", || p.bind(&graph)) {
                        Ok(p) => p,
                        Err(e) => return Response::bad_request(&e.to_string()),
                    }
                }
            }
        }
        None => {
            if let Err(e) = crate::obs::timed(&mut trace, "validate", || {
                crate::graph::validate::validate_stream(&graph, &fseq)
            }) {
                return Response::bad_request(&e.to_string());
            }
            if let Some(resp) = stream_shape_guard(&graph, seq) {
                return resp;
            }
            // admission compile, once per stream: folded constants and
            // eliminated dead getters are paid once per request, not once
            // per decode step
            let prepared = match crate::obs::timed(&mut trace, "opt", || {
                crate::graph::opt::prepare(graph, &fseq, state.optimize)
            }) {
                Ok(p) => p,
                Err(e) => return Response::bad_request(&e.to_string()),
            };
            if let (Some(report), Some(m)) = (prepared.report.as_ref(), state.obs.model(&model)) {
                m.record_opt(report);
            }
            prepared
        }
    };
    let profile = wants_profile(state, req, &body);
    let (tx, rx) = sync_channel::<StreamChunk>(state.stream_buffer);
    let opts = crate::scheduler::SubmitOpts::new()
        .traced(trace)
        .tenant(req.header("x-ndif-auth"))
        .profiled(profile);
    if let Err(e) = service.submit_stream(prepared, steps, tx, state.stream_send_timeout, opts) {
        return submit_error_response(state, e);
    }
    // the chunked source runs on the HTTP worker serving this connection:
    // it pulls frames off the bounded channel and pushes them to the
    // client as they arrive
    let st = Arc::clone(state);
    let deadline = Instant::now() + Duration::from_secs(3600);
    let mut finished = false;
    Response::chunked(
        200,
        "application/x-ndjson",
        Box::new(move || {
            if finished {
                return Chunk::End;
            }
            loop {
                if st.draining.load(Ordering::SeqCst) || Instant::now() >= deadline {
                    // server going down (or stream absurdly old): cut the
                    // connection without the terminator so the peer sees
                    // death, not completion
                    return Chunk::Abort;
                }
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(StreamChunk::Event(e)) => return Chunk::Data(ndjson_line(e)),
                    Ok(StreamChunk::Done(d)) => {
                        finished = true;
                        return Chunk::Data(ndjson_line(d));
                    }
                    Ok(StreamChunk::Failed(err)) => {
                        finished = true;
                        let ev = Json::obj(vec![
                            ("event", Json::from("error")),
                            ("error", Json::from(err)),
                            ("retryable", Json::Bool(false)),
                        ])
                        .to_string();
                        return Chunk::Data(ndjson_line(ev));
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    // worker died without a terminal frame: truncate
                    Err(RecvTimeoutError::Disconnected) => return Chunk::Abort,
                }
            }
        }),
    )
}

fn ndjson_line(mut s: String) -> Vec<u8> {
    s.push('\n');
    s.into_bytes()
}

/// Observability: keys, bytes, and idle age of a live session's state.
/// Gated by the same per-model auth as submitting to that model.
fn session_info_endpoint(state: &Arc<ServerState>, req: &Request, id: &str) -> Response {
    let Some(s) = state.session_state.summary(id) else {
        return Response::not_found();
    };
    if !state.authorize(&s.model, req.header("x-ndif-auth")) {
        return Response::json(401, "{\"error\":\"not authorized for this model\"}".into());
    }
    Response::json(
        200,
        Json::obj(vec![
            ("session", Json::from(id)),
            ("model", Json::from(s.model.as_str())),
            (
                "keys",
                Json::Array(s.keys.iter().map(|k| Json::from(k.as_str())).collect()),
            ),
            ("bytes", Json::from(s.bytes)),
            ("idle_ms", Json::from(s.idle.as_millis() as i64)),
        ])
        .to_string(),
    )
}

/// Explicit end-of-session: drop the state (the client is done). Gated by
/// the same per-model auth as submitting to that model.
fn session_drop_endpoint(state: &Arc<ServerState>, req: &Request, id: &str) -> Response {
    let Some(model) = state.session_state.model_of(id) else {
        return Response::not_found();
    };
    if !state.authorize(&model, req.header("x-ndif-auth")) {
        return Response::json(401, "{\"error\":\"not authorized for this model\"}".into());
    }
    if state.session_state.drop_session(id) {
        Response::json(200, "{\"dropped\":true}".into())
    } else {
        Response::not_found()
    }
}

/// Parse `/v1/result/<id>[?…]` into `(id, timeout_ms)`. `timeout_ms` may
/// appear anywhere in a multi-parameter query; a non-numeric value is a
/// 400, not a silent fallback. Unknown parameters are ignored. Shared with
/// the coordinator front, whose result endpoint has the same shape.
pub(crate) fn parse_result_path(path: &str) -> Result<(&str, u64), Response> {
    let rest = &path["/v1/result/".len()..];
    let (id, query) = match rest.split_once('?') {
        Some((id, q)) => (id, Some(q)),
        None => (rest, None),
    };
    let mut timeout_ms = 30_000u64;
    if let Some(q) = query {
        for pair in q.split('&') {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            if k == "timeout_ms" {
                timeout_ms = v.parse().map_err(|_| {
                    Response::bad_request(&format!("invalid timeout_ms '{v}'"))
                })?;
            }
        }
    }
    Ok((id, timeout_ms))
}

fn result_endpoint(state: &Arc<ServerState>, path: &str) -> Response {
    let (id, timeout_ms) = match parse_result_path(path) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    // wait_outcome evicts completed entries on pickup
    match state.store.wait_outcome(id, Duration::from_millis(timeout_ms)) {
        Some(Ok(json)) => Response::json(200, json),
        Some(Err(e)) => Response::json(500, format!("{{\"error\":{}}}", Json::from(e))),
        None => match state.store.peek(id) {
            Some(Entry::Pending) => {
                Response::json(202, "{\"status\":\"pending\"}".into())
            }
            _ => Response::not_found(),
        },
    }
}

/// `GET /v1/metrics[?format=prometheus]`.
///
/// JSON form: one top-level key per hosted model with the flat service
/// counters (the shape the coordinator's metrics aggregation predates
/// this subsystem and still sums) plus, when observability is on, a
/// nested `"latency"` object of histogram snapshots
/// (e2e/queue_wait/exec/ttft, each with raw buckets and p50/p95/p99) and
/// an `"opt"` object of compiler-pass counters. Keys starting with `_`
/// carry process-wide gauges — `_store` (result-object occupancy),
/// `_sessions` (server-side session state count/bytes), `_endpoints`
/// (per-endpoint request latency), `_obs` — and are transparently
/// skipped by older counter-summing consumers.
fn metrics_endpoint(state: &Arc<ServerState>, path: &str) -> Response {
    let prometheus = path
        .split_once('?')
        .map(|(_, q)| q.split('&').any(|kv| kv == "format=prometheus"))
        .unwrap_or(false);
    let (session_count, session_bytes) =
        (state.session_state.len(), state.session_state.total_bytes());
    if prometheus {
        let mut extra = Vec::new();
        for (name, s) in &state.services {
            let l = s.load();
            for (k, v) in [
                ("enqueued", l.enqueued as f64),
                ("completed", l.completed as f64),
                ("failed", l.failed as f64),
                ("merged_batches", l.merged_batches as f64),
                ("queue_depth", l.queue_depth as f64),
                ("exec_seconds", l.exec_seconds),
            ] {
                extra.push((format!("nnscope_service_{k}{{model=\"{name}\"}}"), v));
            }
        }
        extra.push(("nnscope_store_objects".to_string(), state.store.len() as f64));
        extra.push(("nnscope_session_count".to_string(), session_count as f64));
        extra.push(("nnscope_session_bytes".to_string(), session_bytes as f64));
        extra.push((
            "nnscope_throttled_total".to_string(),
            state.faults.throttled.load(Ordering::Relaxed) as f64,
        ));
        extra.push((
            "nnscope_shed_total".to_string(),
            state.faults.shed.load(Ordering::Relaxed) as f64,
        ));
        extra.push((
            "nnscope_journal_replayed_total".to_string(),
            state.faults.journal_replayed.load(Ordering::Relaxed) as f64,
        ));
        extra.push((
            "nnscope_journal_truncated_bytes".to_string(),
            state.faults.journal_truncated_bytes.load(Ordering::Relaxed) as f64,
        ));
        if let Some(cache) = &state.plans {
            let s = cache.stats();
            for (k, v) in [
                ("nnscope_plan_size", s.size as f64),
                ("nnscope_plan_capacity", s.capacity as f64),
                ("nnscope_plan_hits_total", s.hits as f64),
                ("nnscope_plan_misses_total", s.misses as f64),
                ("nnscope_plan_evictions_total", s.evictions as f64),
                ("nnscope_plan_invalidations_total", s.invalidations as f64),
                ("nnscope_plan_slots_planned", s.slots_planned as f64),
                ("nnscope_plan_values_planned", s.values_planned as f64),
            ] {
                extra.push((k.to_string(), v));
            }
        }
        return Response::bytes(
            200,
            "text/plain; version=0.0.4",
            state.obs.prometheus(&extra).into_bytes(),
        );
    }
    let mut per_model = std::collections::BTreeMap::new();
    for (name, s) in &state.services {
        let l = s.load();
        let mut fields = vec![
            ("enqueued", Json::from(l.enqueued as i64)),
            ("completed", Json::from(l.completed as i64)),
            ("failed", Json::from(l.failed as i64)),
            ("merged_batches", Json::from(l.merged_batches as i64)),
            ("queue_depth", Json::from(l.queue_depth as i64)),
            ("exec_seconds", Json::from(l.exec_seconds)),
        ];
        if let Some(m) = state.obs.model(name) {
            let (latency, opt) = m.to_json();
            fields.push(("latency", latency));
            fields.push(("opt", opt));
            fields.push(("plan", m.plan_json()));
        }
        per_model.insert(name.clone(), Json::obj(fields));
    }
    per_model.insert(
        "_store".to_string(),
        Json::obj(vec![("objects", Json::from(state.store.len() as i64))]),
    );
    per_model.insert(
        "_sessions".to_string(),
        Json::obj(vec![
            ("count", Json::from(session_count as i64)),
            ("bytes", Json::from(session_bytes as i64)),
        ]),
    );
    per_model.insert(
        "_faults".to_string(),
        Json::obj(vec![
            (
                "throttled",
                Json::from(state.faults.throttled.load(Ordering::Relaxed) as i64),
            ),
            ("shed", Json::from(state.faults.shed.load(Ordering::Relaxed) as i64)),
            (
                "journal_replayed",
                Json::from(state.faults.journal_replayed.load(Ordering::Relaxed) as i64),
            ),
            (
                "journal_truncated_bytes",
                Json::from(state.faults.journal_truncated_bytes.load(Ordering::Relaxed) as i64),
            ),
        ]),
    );
    // AOT plan-cache gauges: `enabled` is always present (so consumers
    // can tell --no-plan-cache from a cold cache); the counters only with
    // a live cache
    let plan_obj = match &state.plans {
        Some(cache) => {
            let s = cache.stats();
            Json::obj(vec![
                ("enabled", Json::Bool(true)),
                ("size", Json::from(s.size as i64)),
                ("capacity", Json::from(s.capacity as i64)),
                ("hits", Json::from(s.hits as i64)),
                ("misses", Json::from(s.misses as i64)),
                ("evictions", Json::from(s.evictions as i64)),
                ("invalidations", Json::from(s.invalidations as i64)),
                ("slots_planned", Json::from(s.slots_planned as i64)),
                ("values_planned", Json::from(s.values_planned as i64)),
            ])
        }
        None => Json::obj(vec![("enabled", Json::Bool(false))]),
    };
    per_model.insert("_plan".to_string(), plan_obj);
    per_model.insert("_endpoints".to_string(), state.obs.endpoints_json());
    per_model.insert(
        "_obs".to_string(),
        Json::obj(vec![
            ("enabled", Json::Bool(state.obs.enabled())),
            ("plan_cache", Json::Bool(state.plans.is_some())),
        ]),
    );
    Response::json(200, Json::Object(per_model).to_string())
}

/// `GET /v1/debug/requests`: the bounded ring of recently finished
/// request traces, oldest first.
fn debug_requests_endpoint(state: &Arc<ServerState>) -> Response {
    Response::json(
        200,
        Json::obj(vec![("requests", Json::Array(state.obs.ring().snapshot()))]).to_string(),
    )
}

/// `GET /v1/debug/profile/<id>`: the deep profile of a finished profiled
/// request as Chrome/Perfetto trace-event JSON (load it at ui.perfetto.dev
/// or chrome://tracing). Profiles live in a bounded most-recent ring
/// ([`NdifConfig::profile_ring`]); evicted or unknown ids are 404.
fn debug_profile_endpoint(state: &Arc<ServerState>, id: &str) -> Response {
    match state.obs.profile().ring.get(id) {
        Some(j) => Response::json(200, j.to_string()),
        None => Response::not_found(),
    }
}

/// `GET /v1/debug/hotops`: this replica's cumulative per-op self-time
/// table across every profiled request since boot. The coordinator's
/// `/v1/fleet/hotops` merges these across replicas, so the full op table
/// is returned (op kinds are few); `share` is the fraction of total
/// profiled self-time.
fn debug_hotops_endpoint(state: &Arc<ServerState>) -> Response {
    Response::json(200, state.obs.profile().hotops.to_json(64).to_string())
}
