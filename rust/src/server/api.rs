//! NDIF HTTP API: routing, auth, request validation, metrics.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::graph::serde as gserde;
use crate::json::{parse, Json};
use crate::models::ModelRunner;
use crate::scheduler::{CoTenancy, ModelService};

use super::http::{Handler, HttpServer, Request, Response};
use super::store::{Entry, ObjectStore};

/// Server configuration.
#[derive(Clone)]
pub struct NdifConfig {
    /// Bind address; use port 0 for ephemeral.
    pub addr: String,
    /// HTTP worker threads.
    pub workers: usize,
    /// Models to preload.
    pub models: Vec<String>,
    /// Artifacts directory.
    pub artifacts: PathBuf,
    /// Co-tenancy policy for every model service.
    pub cotenancy: CoTenancy,
    /// Per-model allowed auth tokens; models absent from the map are open.
    /// (Stands in for the paper's HuggingFace-gated model authorization.)
    pub auth: HashMap<String, Vec<String>>,
}

impl NdifConfig {
    pub fn local(models: &[&str]) -> NdifConfig {
        NdifConfig {
            addr: "127.0.0.1:0".into(),
            workers: 8,
            models: models.iter().map(|s| s.to_string()).collect(),
            artifacts: crate::models::artifacts_dir(),
            cotenancy: CoTenancy::Sequential,
            auth: HashMap::new(),
        }
    }
}

struct ServerState {
    services: HashMap<String, ModelService>,
    store: Arc<ObjectStore>,
    next_id: AtomicU64,
    auth: HashMap<String, Vec<String>>,
}

impl ServerState {
    fn authorize(&self, model: &str, token: Option<&str>) -> bool {
        match self.auth.get(model) {
            None => true,
            Some(allowed) => token.map(|t| allowed.iter().any(|a| a == t)).unwrap_or(false),
        }
    }
}

/// A running NDIF server.
pub struct NdifServer {
    http: HttpServer,
    state: Arc<ServerState>,
}

impl NdifServer {
    /// Preload the configured models and start serving.
    pub fn start(cfg: NdifConfig) -> Result<NdifServer> {
        let store = Arc::new(ObjectStore::new());
        let mut services = HashMap::new();
        for name in &cfg.models {
            let runner = Arc::new(
                ModelRunner::load(&cfg.artifacts, name)
                    .with_context(|| format!("preload model {name}"))?,
            );
            services.insert(
                name.clone(),
                ModelService::start(runner, Arc::clone(&store), cfg.cotenancy),
            );
        }
        let state = Arc::new(ServerState {
            services,
            store,
            next_id: AtomicU64::new(1),
            auth: cfg.auth.clone(),
        });
        let s2 = Arc::clone(&state);
        let handler: Handler = Arc::new(move |req| route(&s2, req));
        let http = HttpServer::bind(&cfg.addr, cfg.workers, handler)?;
        Ok(NdifServer { http, state })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr()
    }

    /// Metrics snapshot for a model (enqueued, completed, failed, merged).
    pub fn metrics(&self, model: &str) -> Option<(u64, u64, u64, u64)> {
        self.state.services.get(model).map(|s| {
            (
                s.metrics.enqueued.load(Ordering::Relaxed),
                s.metrics.completed.load(Ordering::Relaxed),
                s.metrics.failed.load(Ordering::Relaxed),
                s.metrics.merged_batches.load(Ordering::Relaxed),
            )
        })
    }

    pub fn shutdown(&mut self) {
        self.http.shutdown();
    }
}

fn route(state: &Arc<ServerState>, req: Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Response::text(200, "ok"),
        ("GET", "/v1/models") => models_endpoint(state),
        ("POST", "/v1/trace") => trace_endpoint(state, &req),
        ("POST", "/v1/session") => session_endpoint(state, &req),
        ("GET", "/v1/metrics") => metrics_endpoint(state),
        ("GET", path) if path.starts_with("/v1/result/") => result_endpoint(state, path),
        _ => Response::not_found(),
    }
}

fn models_endpoint(state: &Arc<ServerState>) -> Response {
    let models: Vec<Json> = state
        .services
        .values()
        .map(|s| {
            let m = &s.runner.manifest;
            Json::obj(vec![
                ("name", Json::from(m.name.as_str())),
                ("params", Json::from(m.param_count)),
                ("n_layers", Json::from(m.n_layers)),
                ("seq", Json::from(m.seq)),
                ("batches", Json::from(m.batches.clone())),
                ("simulates", Json::from(m.simulates.as_str())),
                ("grad", Json::from(m.grad)),
                ("tp", Json::from(m.tp.clone())),
            ])
        })
        .collect();
    Response::json(200, Json::obj(vec![("models", Json::Array(models))]).to_string())
}

fn submit_graph(state: &Arc<ServerState>, req: &Request, body: &Json) -> Result<String, Response> {
    let graph = gserde::from_json(body).map_err(|e| Response::bad_request(&e.to_string()))?;
    let Some(service) = state.services.get(&graph.model) else {
        return Err(Response::json(
            404,
            format!("{{\"error\":\"model '{}' not hosted\"}}", graph.model),
        ));
    };
    if !state.authorize(&graph.model, req.header("x-ndif-auth")) {
        return Err(Response::json(
            401,
            "{\"error\":\"not authorized for this model\"}".into(),
        ));
    }
    // early validation against the manifest so bad graphs fail at submit
    let fseq = service.runner.manifest.forward_sequence();
    if let Err(e) = crate::graph::validate::validate(&graph, &fseq) {
        return Err(Response::bad_request(&e.to_string()));
    }
    let id = format!("r-{}", state.next_id.fetch_add(1, Ordering::Relaxed));
    state.store.put_pending(&id);
    service
        .submit(id.clone(), graph)
        .map_err(|e| Response::json(503, format!("{{\"error\":{}}}", Json::from(e.to_string()))))?;
    Ok(id)
}

fn trace_endpoint(state: &Arc<ServerState>, req: &Request) -> Response {
    let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
        parse(s).map_err(|e| e.to_string())
    }) {
        Ok(j) => j,
        Err(e) => return Response::bad_request(&e),
    };
    match submit_graph(state, req, &body) {
        Ok(id) => Response::json(202, Json::obj(vec![("id", Json::from(id))]).to_string()),
        Err(resp) => resp,
    }
}

/// A Session: multiple traces executed in order within one request
/// (§B.1 "Remote Execution and Session"). Sent as
/// `{"traces": [graph, graph, ...]}`; FIFO queueing per model preserves
/// order, and the response bundles all results, eliminating per-trace
/// round trips.
fn session_endpoint(state: &Arc<ServerState>, req: &Request) -> Response {
    let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
        parse(s).map_err(|e| e.to_string())
    }) {
        Ok(j) => j,
        Err(e) => return Response::bad_request(&e),
    };
    let Some(traces) = body.get("traces").as_array() else {
        return Response::bad_request("session missing traces");
    };
    let mut ids = Vec::with_capacity(traces.len());
    for t in traces {
        match submit_graph(state, req, t) {
            Ok(id) => ids.push(id),
            Err(resp) => return resp,
        }
    }
    // gather all results (bounded wait per trace)
    let mut results = Vec::with_capacity(ids.len());
    for id in &ids {
        match state.store.wait_outcome(id, Duration::from_secs(300)) {
            Some(Ok(json)) => {
                state.store.remove(id);
                match parse(&json) {
                    Ok(j) => results.push(j),
                    Err(e) => return Response::json(500, format!("{{\"error\":\"{e}\"}}")),
                }
            }
            Some(Err(e)) => {
                state.store.remove(id);
                return Response::json(500, format!("{{\"error\":{}}}", Json::from(e)));
            }
            None => return Response::json(500, "{\"error\":\"session timeout\"}".into()),
        }
    }
    Response::json(
        200,
        Json::obj(vec![("results", Json::Array(results))]).to_string(),
    )
}

fn result_endpoint(state: &Arc<ServerState>, path: &str) -> Response {
    // /v1/result/<id>[?timeout_ms=N]
    let rest = &path["/v1/result/".len()..];
    let (id, timeout_ms) = match rest.split_once('?') {
        Some((id, q)) => {
            let t = q
                .strip_prefix("timeout_ms=")
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(30_000);
            (id, t)
        }
        None => (rest, 30_000u64),
    };
    match state.store.wait_outcome(id, Duration::from_millis(timeout_ms)) {
        Some(Ok(json)) => {
            state.store.remove(id);
            Response::json(200, json)
        }
        Some(Err(e)) => {
            state.store.remove(id);
            Response::json(500, format!("{{\"error\":{}}}", Json::from(e)))
        }
        None => match state.store.peek(id) {
            Some(Entry::Pending) => {
                Response::json(202, "{\"status\":\"pending\"}".into())
            }
            _ => Response::not_found(),
        },
    }
}

fn metrics_endpoint(state: &Arc<ServerState>) -> Response {
    let mut per_model = std::collections::BTreeMap::new();
    for (name, s) in &state.services {
        per_model.insert(
            name.clone(),
            Json::obj(vec![
                ("enqueued", Json::from(s.metrics.enqueued.load(Ordering::Relaxed) as i64)),
                ("completed", Json::from(s.metrics.completed.load(Ordering::Relaxed) as i64)),
                ("failed", Json::from(s.metrics.failed.load(Ordering::Relaxed) as i64)),
                (
                    "merged_batches",
                    Json::from(s.metrics.merged_batches.load(Ordering::Relaxed) as i64),
                ),
                (
                    "queue_depth",
                    Json::from(s.metrics.queue_depth.load(Ordering::Relaxed) as i64),
                ),
                (
                    "exec_seconds",
                    Json::from(s.metrics.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9),
                ),
            ]),
        );
    }
    Response::json(200, Json::Object(per_model).to_string())
}
