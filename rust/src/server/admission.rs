//! Per-tenant admission control: token-bucket rate limiting and
//! watermark-based load shedding.
//!
//! A shared fleet is only shared if one tenant's burst cannot starve the
//! rest (the eDIF pilot's headline operational finding). Admission is
//! decided at the HTTP front door, keyed by the request's auth token:
//!
//! * **Token bucket per tenant** — capacity `burst`, refill `per_s`.
//!   A drained bucket yields `429 {"retryable":true,"retry_after_ms":…}`
//!   plus a `Retry-After` header; the client retry policy honors it.
//!   429 is the *tenant's* backpressure signal — unlike a 503 it must not
//!   trigger replica failover (the next replica would just see the same
//!   overdrawn bucket).
//! * **Load-shed watermarks** — when total queue depth crosses
//!   `shed_anon_above`, anonymous (tokenless) work is shed first with a
//!   retryable 503; past `shed_all_above` everything is shed. Shedding at
//!   the door keeps queue wait bounded for admitted work instead of
//!   timing out everyone equally.
//!
//! Buckets for idle tenants are pruned opportunistically so the map stays
//! proportional to the *active* tenant set.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Token-bucket parameters (per tenant).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateLimit {
    /// Sustained admission rate, requests per second.
    pub per_s: f64,
    /// Burst capacity: how far a tenant can run ahead of the sustained
    /// rate before being throttled.
    pub burst: f64,
}

impl RateLimit {
    pub fn new(per_s: f64, burst: f64) -> RateLimit {
        assert!(per_s > 0.0, "rate must be positive");
        RateLimit { per_s, burst: burst.max(1.0) }
    }
}

/// Outcome of an admission check.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    Admit,
    /// Over the rate limit; come back after `retry_after`.
    Throttle { retry_after: Duration },
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Thread-safe per-tenant token buckets.
pub struct AdmissionControl {
    limit: RateLimit,
    buckets: Mutex<Buckets>,
}

struct Buckets {
    map: HashMap<String, Bucket>,
    last_prune: Instant,
}

/// A bucket full for this long is indistinguishable from absent: prune it.
const IDLE_PRUNE: Duration = Duration::from_secs(120);

impl AdmissionControl {
    pub fn new(limit: RateLimit) -> AdmissionControl {
        AdmissionControl {
            limit,
            buckets: Mutex::new(Buckets { map: HashMap::new(), last_prune: Instant::now() }),
        }
    }

    pub fn limit(&self) -> RateLimit {
        self.limit
    }

    /// Try to admit one request for `tenant` (the auth token, or a fixed
    /// key such as `"anon"` for tokenless traffic).
    pub fn check(&self, tenant: &str) -> Decision {
        self.check_at(tenant, Instant::now())
    }

    /// Clock-explicit variant (tests drive virtual time through it).
    pub fn check_at(&self, tenant: &str, now: Instant) -> Decision {
        let mut g = self.buckets.lock().unwrap();
        if now.duration_since(g.last_prune) > IDLE_PRUNE {
            g.last_prune = now;
            let limit = self.limit;
            g.map.retain(|_, b| {
                let refilled = b.tokens
                    + now.saturating_duration_since(b.last).as_secs_f64() * limit.per_s;
                refilled < limit.burst
            });
        }
        let b = g.map.entry(tenant.to_string()).or_insert(Bucket {
            tokens: self.limit.burst,
            last: now,
        });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * self.limit.per_s).min(self.limit.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Decision::Admit
        } else {
            let deficit = 1.0 - b.tokens;
            Decision::Throttle {
                retry_after: Duration::from_secs_f64(deficit / self.limit.per_s),
            }
        }
    }
}

/// Queue-depth watermarks for graceful load shedding.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedPolicy {
    /// Above this total queue depth, anonymous work is shed.
    pub shed_anon_above: usize,
    /// Above this total queue depth, everything is shed.
    pub shed_all_above: usize,
}

impl ShedPolicy {
    /// Effectively disabled (watermarks at infinity).
    pub fn disabled() -> ShedPolicy {
        ShedPolicy { shed_anon_above: usize::MAX, shed_all_above: usize::MAX }
    }

    /// Should a request from this tenant class be shed at this depth?
    /// Lowest-priority (anonymous) work goes first.
    pub fn shed(&self, queue_depth: usize, anonymous: bool) -> bool {
        if queue_depth > self.shed_all_above {
            return true;
        }
        anonymous && queue_depth > self.shed_anon_above
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_throttle_then_refill() {
        let ac = AdmissionControl::new(RateLimit::new(10.0, 3.0));
        let t0 = Instant::now();
        for _ in 0..3 {
            assert_eq!(ac.check_at("alice", t0), Decision::Admit);
        }
        let d = ac.check_at("alice", t0);
        let Decision::Throttle { retry_after } = d else {
            panic!("4th burst request must throttle, got {d:?}");
        };
        // one token refills in 1/per_s = 100ms
        assert!(retry_after <= Duration::from_millis(101), "{retry_after:?}");
        assert!(retry_after >= Duration::from_millis(80), "{retry_after:?}");
        // after the advertised wait, admission resumes
        assert_eq!(ac.check_at("alice", t0 + retry_after + Duration::from_millis(1)), Decision::Admit);
    }

    #[test]
    fn tenants_are_isolated() {
        let ac = AdmissionControl::new(RateLimit::new(5.0, 2.0));
        let t0 = Instant::now();
        // alice drains her bucket …
        assert_eq!(ac.check_at("alice", t0), Decision::Admit);
        assert_eq!(ac.check_at("alice", t0), Decision::Admit);
        assert!(matches!(ac.check_at("alice", t0), Decision::Throttle { .. }));
        // … bob is untouched
        assert_eq!(ac.check_at("bob", t0), Decision::Admit);
    }

    #[test]
    fn sustained_rate_is_enforced() {
        let ac = AdmissionControl::new(RateLimit::new(100.0, 10.0));
        let t0 = Instant::now();
        // offer 10× the sustained rate for one simulated second
        let mut admitted = 0;
        for i in 0..1000 {
            let now = t0 + Duration::from_micros(i * 1000);
            if ac.check_at("greedy", now) == Decision::Admit {
                admitted += 1;
            }
        }
        // burst (10) + refill (~100) with a little slack
        assert!(admitted <= 115, "admitted {admitted} of 1000 at 10x rate");
        assert!(admitted >= 100, "admitted {admitted}, refill undercounted");
    }

    #[test]
    fn tokens_cap_at_burst() {
        let ac = AdmissionControl::new(RateLimit::new(1000.0, 2.0));
        let t0 = Instant::now();
        assert_eq!(ac.check_at("t", t0), Decision::Admit);
        // a long idle period must not bank more than `burst` tokens
        let later = t0 + Duration::from_secs(3600);
        assert_eq!(ac.check_at("t", later), Decision::Admit);
        assert_eq!(ac.check_at("t", later), Decision::Admit);
        assert!(matches!(ac.check_at("t", later), Decision::Throttle { .. }));
    }

    #[test]
    fn shed_policy_priorities() {
        let p = ShedPolicy { shed_anon_above: 10, shed_all_above: 50 };
        assert!(!p.shed(5, true));
        assert!(!p.shed(5, false));
        assert!(p.shed(11, true), "anonymous shed first");
        assert!(!p.shed(11, false), "authenticated ride out the first watermark");
        assert!(p.shed(51, false), "everything sheds past the high watermark");
        let off = ShedPolicy::disabled();
        assert!(!off.shed(usize::MAX - 1, true));
    }
}
