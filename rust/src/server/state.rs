//! Server-side session state: named tensor variables that live *in the
//! fabric* across traces (paper §B.1 Code Example 5, "Remote Execution and
//! Session").
//!
//! Each session owns a keyed map of tensors — probe weights, LoRA
//! adapters, optimizer moments — created and updated by `Op::StoreState`
//! nodes and read by `Op::LoadState` nodes. Keeping this state co-resident
//! with the model turns an N-step training loop from 2N WAN transfers
//! into 2 (upload the trace bundle once, download the saved scalars once).
//!
//! Lifecycle:
//! * **create** — a session entry is opened on first use (`open`);
//! * **read** — each trace executes against a [`snapshot`] of the values
//!   as of trace start (loads are pre-phase);
//! * **update** — the trace's collected store updates [`commit`]
//!   atomically after it completes (post-phase), with byte accounting
//!   against a per-session budget;
//! * **drop** — explicit end-of-session, or TTL expiry for sessions a
//!   client abandoned (swept opportunistically on every open/commit).
//!
//! [`snapshot`]: SessionStateStore::snapshot
//! [`commit`]: SessionStateStore::commit

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

/// Budget and expiry knobs for a [`SessionStateStore`].
#[derive(Clone, Copy, Debug)]
pub struct StateLimits {
    /// Upper bound on one session's tensor bytes (f32 payload).
    pub max_bytes_per_session: usize,
    /// Upper bound on live sessions.
    pub max_sessions: usize,
    /// Sessions untouched for longer than this are expired.
    pub ttl: Duration,
}

impl Default for StateLimits {
    fn default() -> StateLimits {
        StateLimits {
            max_bytes_per_session: 64 << 20, // 64 MiB of parameters
            max_sessions: 1024,
            ttl: Duration::from_secs(600),
        }
    }
}

struct SessionEntry {
    /// The model this session is bound to: state lives with one model
    /// service, and an id collision across models is a client error, not a
    /// silent shared namespace.
    model: String,
    vars: HashMap<String, Tensor>,
    bytes: usize,
    last_touch: Instant,
}

/// Point-in-time description of one session's state (observability).
#[derive(Clone, Debug)]
pub struct SessionSummary {
    pub model: String,
    pub keys: Vec<String>,
    pub bytes: usize,
    pub idle: Duration,
}

/// Thread-safe store of per-session named tensors.
pub struct SessionStateStore {
    sessions: Mutex<HashMap<String, SessionEntry>>,
    limits: StateLimits,
}

fn tensor_bytes(t: &Tensor) -> usize {
    t.numel() * std::mem::size_of::<f32>()
}

impl Default for SessionStateStore {
    fn default() -> Self {
        Self::new(StateLimits::default())
    }
}

impl SessionStateStore {
    pub fn new(limits: StateLimits) -> SessionStateStore {
        SessionStateStore { sessions: Mutex::new(HashMap::new()), limits }
    }

    pub fn limits(&self) -> StateLimits {
        self.limits
    }

    /// Create the session (bound to `model`) if absent and refresh its TTL
    /// clock. Errors when the store is at its session cap and the id is
    /// new, or when the id already exists bound to a different model.
    pub fn open(&self, id: &str, model: &str) -> Result<()> {
        let mut g = self.sessions.lock().unwrap();
        Self::sweep(&mut g, self.limits.ttl);
        if let Some(e) = g.get_mut(id) {
            if e.model != model {
                return Err(anyhow!(
                    "session '{id}' is bound to model '{}', not '{model}'",
                    e.model
                ));
            }
            e.last_touch = Instant::now();
            return Ok(());
        }
        if g.len() >= self.limits.max_sessions {
            return Err(anyhow!(
                "session-state store full ({} sessions)",
                self.limits.max_sessions
            ));
        }
        g.insert(
            id.to_string(),
            SessionEntry {
                model: model.to_string(),
                vars: HashMap::new(),
                bytes: 0,
                last_touch: Instant::now(),
            },
        );
        Ok(())
    }

    /// The model a live session is bound to.
    pub fn model_of(&self, id: &str) -> Option<String> {
        self.sessions.lock().unwrap().get(id).map(|e| e.model.clone())
    }

    /// Clone the session's variables (the state view a trace executes
    /// against). None = unknown/expired session.
    pub fn snapshot(&self, id: &str) -> Option<HashMap<String, Tensor>> {
        let mut g = self.sessions.lock().unwrap();
        let e = g.get_mut(id)?;
        e.last_touch = Instant::now();
        Some(e.vars.clone())
    }

    /// Keys currently present in a session (validation of follow-up
    /// trace bundles).
    pub fn keys(&self, id: &str) -> Option<BTreeSet<String>> {
        let g = self.sessions.lock().unwrap();
        Some(g.get(id)?.vars.keys().cloned().collect())
    }

    /// Commit a trace's store updates atomically: either every update
    /// lands or (over budget / unknown session) none do.
    pub fn commit(&self, id: &str, updates: BTreeMap<String, Tensor>) -> Result<()> {
        let mut g = self.sessions.lock().unwrap();
        Self::sweep(&mut g, self.limits.ttl);
        let e = g
            .get_mut(id)
            .ok_or_else(|| anyhow!("session '{id}' unknown or expired"))?;
        let mut bytes = e.bytes;
        for (k, v) in &updates {
            bytes += tensor_bytes(v);
            if let Some(old) = e.vars.get(k) {
                bytes -= tensor_bytes(old);
            }
        }
        if bytes > self.limits.max_bytes_per_session {
            return Err(anyhow!(
                "session '{id}' state budget exceeded: {bytes} bytes > {} byte cap",
                self.limits.max_bytes_per_session
            ));
        }
        for (k, v) in updates {
            e.vars.insert(k, v);
        }
        e.bytes = bytes;
        e.last_touch = Instant::now();
        Ok(())
    }

    /// End a session, freeing its tensors. Returns whether it existed.
    pub fn drop_session(&self, id: &str) -> bool {
        self.sessions.lock().unwrap().remove(id).is_some()
    }

    /// Observability snapshot for `GET /v1/session/<id>`.
    pub fn summary(&self, id: &str) -> Option<SessionSummary> {
        let g = self.sessions.lock().unwrap();
        let e = g.get(id)?;
        let mut keys: Vec<String> = e.vars.keys().cloned().collect();
        keys.sort();
        Some(SessionSummary {
            model: e.model.clone(),
            keys,
            bytes: e.bytes,
            idle: e.last_touch.elapsed(),
        })
    }

    /// Expire sessions idle past the TTL (also runs opportunistically on
    /// every open/commit). Returns how many were dropped.
    pub fn expire(&self) -> usize {
        let mut g = self.sessions.lock().unwrap();
        let before = g.len();
        Self::sweep(&mut g, self.limits.ttl);
        before - g.len()
    }

    fn sweep(g: &mut HashMap<String, SessionEntry>, ttl: Duration) {
        g.retain(|_, e| e.last_touch.elapsed() <= ttl);
    }

    /// Live session count.
    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total tensor bytes held across all sessions.
    pub fn total_bytes(&self) -> usize {
        self.sessions.lock().unwrap().values().map(|e| e.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(limits: StateLimits) -> SessionStateStore {
        SessionStateStore::new(limits)
    }

    #[test]
    fn create_read_update_lifecycle() {
        let s = store(StateLimits::default());
        s.open("a", "tiny-sim").unwrap();
        assert!(s.snapshot("a").unwrap().is_empty());
        assert_eq!(s.model_of("a").as_deref(), Some("tiny-sim"));
        // the id is bound to its model: reuse under another model is an error
        assert!(s.open("a", "other-model").is_err());
        let mut up = BTreeMap::new();
        up.insert("w".to_string(), Tensor::full(&[2, 2], 1.0));
        s.commit("a", up).unwrap();
        assert_eq!(s.snapshot("a").unwrap()["w"].data(), &[1.0; 4]);
        assert_eq!(s.keys("a").unwrap().len(), 1);
        assert_eq!(s.total_bytes(), 16);

        // update in place: byte accounting replaces, not accumulates
        let mut up = BTreeMap::new();
        up.insert("w".to_string(), Tensor::full(&[2, 2], 2.0));
        s.commit("a", up).unwrap();
        assert_eq!(s.total_bytes(), 16);

        assert!(s.drop_session("a"));
        assert!(s.snapshot("a").is_none());
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn byte_budget_enforced_atomically() {
        let s = store(StateLimits { max_bytes_per_session: 32, ..Default::default() });
        s.open("a", "m").unwrap();
        let mut up = BTreeMap::new();
        up.insert("small".to_string(), Tensor::full(&[4], 0.0)); // 16 B
        up.insert("big".to_string(), Tensor::full(&[8], 0.0)); // 32 B → 48 total
        assert!(s.commit("a", up).is_err());
        // nothing landed
        assert!(s.snapshot("a").unwrap().is_empty());
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn session_cap_enforced() {
        let s = store(StateLimits { max_sessions: 2, ..Default::default() });
        s.open("a", "m").unwrap();
        s.open("b", "m").unwrap();
        assert!(s.open("c", "m").is_err());
        // reopening an existing session is fine at the cap
        s.open("a", "m").unwrap();
    }

    #[test]
    fn ttl_expires_abandoned_sessions() {
        let s = store(StateLimits { ttl: Duration::from_millis(20), ..Default::default() });
        s.open("a", "m").unwrap();
        let mut up = BTreeMap::new();
        up.insert("w".to_string(), Tensor::full(&[1], 0.0));
        s.commit("a", up).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(s.expire(), 1);
        assert!(s.snapshot("a").is_none());
        // committing into an expired session is an error, not a revival
        let mut up = BTreeMap::new();
        up.insert("w".to_string(), Tensor::full(&[1], 0.0));
        assert!(s.commit("a", up).is_err());
    }

    #[test]
    fn touch_keeps_sessions_alive() {
        let s = store(StateLimits { ttl: Duration::from_millis(60), ..Default::default() });
        s.open("a", "m").unwrap();
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(20));
            assert!(s.snapshot("a").is_some(), "touched session must not expire");
        }
        let sum = s.summary("a").unwrap();
        assert!(sum.keys.is_empty());
        assert!(sum.idle < Duration::from_millis(60));
    }
}
