//! Minimal HTTP/1.1 server and client over `std::net`.
//!
//! The NDIF frontend is an HTTP service ("the system serializes the
//! intervention graph into a custom JSON format and sends it to NDIF's
//! HTTP server front-end", §B.2). No async stack is available offline, so
//! this is a small, correct, thread-pool-backed HTTP/1.1 implementation:
//! request line + headers + Content-Length bodies, one connection per
//! request (`Connection: close`). That is all the NDIF protocol needs, and
//! it keeps the request path free of hidden allocation or buffering
//! surprises when we profile it (§Perf).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::threadpool::ThreadPool;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body not utf-8")
    }
}

/// One pull from a chunked-response source.
pub enum Chunk {
    /// Bytes to send as one transfer chunk (empty slices are skipped — a
    /// zero-length chunk is the HTTP terminator).
    Data(Vec<u8>),
    /// Clean end of stream: the terminating zero chunk is written.
    End,
    /// Abort: drop the connection WITHOUT the terminator, so the peer can
    /// tell truncation from completion (mid-stream failure semantics).
    Abort,
}

/// Pull-based producer for a chunked response body. Called repeatedly by
/// the connection handler until it returns `End` or `Abort`.
pub type ChunkSource = Box<dyn FnMut() -> Chunk + Send>;

/// An HTTP response under construction: either a complete body
/// (Content-Length) or a streamed one (Transfer-Encoding: chunked).
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on 429/503).
    pub headers: Vec<(String, String)>,
    /// When set, `body` is ignored and the response streams chunks pulled
    /// from this source.
    pub stream: Option<ChunkSource>,
}

impl std::fmt::Debug for Response {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Response")
            .field("status", &self.status)
            .field("content_type", &self.content_type)
            .field("body_len", &self.body.len())
            .field("streamed", &self.stream.is_some())
            .finish()
    }
}

impl Response {
    /// A complete (non-streamed) response.
    pub fn bytes(status: u16, content_type: &'static str, body: Vec<u8>) -> Response {
        Response { status, content_type, body, headers: Vec::new(), stream: None }
    }

    pub fn json(status: u16, body: String) -> Response {
        Response::bytes(status, "application/json", body.into_bytes())
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response::bytes(status, "text/plain", body.as_bytes().to_vec())
    }

    /// A chunked (streaming) response; the body is produced incrementally
    /// by `source`.
    pub fn chunked(status: u16, content_type: &'static str, source: ChunkSource) -> Response {
        Response {
            status,
            content_type,
            body: Vec::new(),
            headers: Vec::new(),
            stream: Some(source),
        }
    }

    /// Attach an extra response header (builder style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }

    pub fn bad_request(msg: &str) -> Response {
        Response::json(400, format!("{{\"error\":{}}}", crate::json::Json::from(msg)))
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            401 => "Unauthorized",
            404 => "Not Found",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// Read one request from a stream.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();

    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_string();
            let v = v.trim().to_string();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse().context("bad content-length")?;
            }
            headers.push((k, v));
        }
    }
    const MAX_BODY: usize = 256 * 1024 * 1024;
    if content_length > MAX_BODY {
        return Err(anyhow!("body too large: {content_length}"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, headers, body })
}

/// Write a response (and close the connection). Streamed responses are
/// written chunk-by-chunk with `Transfer-Encoding: chunked`, each chunk
/// flushed as it is produced so the peer sees events as they happen; an
/// `Abort` pull drops the connection without the terminating zero chunk.
pub fn write_response(stream: &mut TcpStream, resp: &mut Response) -> Result<()> {
    let mut extra = String::new();
    for (k, v) in &resp.headers {
        extra.push_str(&format!("{k}: {v}\r\n"));
    }
    let Some(mut source) = resp.stream.take() else {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n",
            resp.status,
            resp.status_text(),
            resp.content_type,
            resp.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&resp.body)?;
        stream.flush()?;
        return Ok(());
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\n{extra}Connection: close\r\n\r\n",
        resp.status,
        resp.status_text(),
        resp.content_type,
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    loop {
        match source() {
            Chunk::Data(d) => {
                if d.is_empty() {
                    continue; // a zero-length chunk would terminate the body
                }
                stream.write_all(format!("{:x}\r\n", d.len()).as_bytes())?;
                stream.write_all(&d)?;
                stream.write_all(b"\r\n")?;
                stream.flush()?;
            }
            Chunk::End => {
                stream.write_all(b"0\r\n\r\n")?;
                stream.flush()?;
                return Ok(());
            }
            Chunk::Abort => {
                return Err(anyhow!("chunked response aborted mid-stream"));
            }
        }
    }
}

/// Handler signature: pure request → response.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync + 'static>;

/// A running HTTP server (accept loop + worker pool). Dropping shuts down.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind to `addr` (use port 0 for ephemeral) and serve on `workers`
    /// pool threads.
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("nnscope-http-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            // bound per-write stalls so a wedged client
                            // cannot pin a worker (and with it, shutdown)
                            // forever; slow-but-progressing clients are
                            // unaffected (the bound is per write, not per
                            // response)
                            let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
                            let handler = Arc::clone(&handler);
                            pool.execute(move || {
                                let mut resp = match read_request(&mut stream) {
                                    Ok(req) => handler(req),
                                    Err(e) => Response::bad_request(&e.to_string()),
                                };
                                let _ = write_response(&mut stream, &mut resp);
                            });
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Perform one HTTP request; returns (status, body). No socket timeouts:
/// the call blocks as long as the server holds the response (long-poll
/// clients rely on this).
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> Result<(u16, Vec<u8>)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    request_on(stream, addr, method, path, body, extra_headers)
}

/// Like [`http_request`] but with a connect deadline and socket read/write
/// timeouts, so a hung peer cannot block the caller forever (the
/// coordinator's proxy and probe paths). The read timeout must exceed any
/// server-side long-poll hold the request asks for.
pub fn http_request_timeout(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
    timeout: Duration,
) -> Result<(u16, Vec<u8>)> {
    http_request_deadlines(addr, method, path, body, extra_headers, timeout, timeout)
}

/// [`http_request_timeout`] with separate bounds: `connect` caps the TCP
/// handshake and request writes (detects unreachable peers fast), `read`
/// caps waiting for the response (longer for endpoints that legitimately
/// hold, e.g. a synchronous session run).
pub fn http_request_deadlines(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
    connect: Duration,
    read: Duration,
) -> Result<(u16, Vec<u8>)> {
    let connect = connect.max(Duration::from_millis(1)); // zero would disable
    let read = read.max(Duration::from_millis(1));
    let stream = TcpStream::connect_timeout(&addr, connect)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_write_timeout(Some(connect))?;
    stream.set_read_timeout(Some(read))?;
    request_on(stream, addr, method, path, body, extra_headers)
}

fn write_request_head(
    stream: &mut TcpStream,
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> Result<()> {
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Parse a response's status line + headers, returning
/// `(status, content_length, chunked)`.
fn read_response_head(reader: &mut BufReader<TcpStream>) -> Result<(u16, Option<usize>, bool)> {
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?
        .parse()
        .context("bad status code")?;
    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = Some(v.trim().parse().context("bad content-length")?);
            } else if k.eq_ignore_ascii_case("transfer-encoding")
                && v.trim().eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
    }
    Ok((status, content_length, chunked))
}

fn request_on(
    mut stream: TcpStream,
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> Result<(u16, Vec<u8>)> {
    write_request_head(&mut stream, addr, method, path, body, extra_headers)?;
    let mut reader = BufReader::new(stream);
    let (status, content_length, chunked) = read_response_head(&mut reader)?;
    if chunked {
        // a non-streaming caller of a streaming endpoint still gets the
        // whole body, de-chunked
        let mut hs = HttpStream::new(reader, None, true);
        let body = hs.read_body().context("read chunked body")?;
        return Ok((status, body));
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body)?;
        }
        None => {
            reader.read_to_end(&mut body)?;
        }
    }
    Ok((status, body))
}

// ---------------------------------------------------------------------------
// Streaming client
// ---------------------------------------------------------------------------

enum Transfer {
    /// chunked transfer: bytes left in the current chunk.
    Chunked { left: usize },
    /// Content-Length body: bytes left.
    Length { left: usize },
    /// EOF-delimited body (no framing; end cannot be told from truncation).
    Eof,
}

/// The body of an in-flight HTTP response, decoded incrementally — the
/// client half of chunked-transfer streaming. `next_line()` yields
/// NDJSON event lines as the server produces them; a connection that dies
/// before the chunked terminator surfaces as `UnexpectedEof`, so callers
/// can distinguish mid-stream death from completion.
pub struct HttpStream {
    reader: BufReader<TcpStream>,
    transfer: Transfer,
    done: bool,
    buf: Vec<u8>,
}

impl HttpStream {
    fn new(reader: BufReader<TcpStream>, content_length: Option<usize>, chunked: bool) -> Self {
        let transfer = if chunked {
            Transfer::Chunked { left: 0 }
        } else if let Some(n) = content_length {
            Transfer::Length { left: n }
        } else {
            Transfer::Eof
        };
        HttpStream { reader, transfer, done: false, buf: Vec::new() }
    }

    /// Decode more body bytes into the buffer. Ok(false) = clean end of
    /// body; Err(UnexpectedEof) = the peer vanished mid-body.
    fn fill(&mut self) -> std::io::Result<bool> {
        use std::io::{Error, ErrorKind, Read};
        if self.done {
            return Ok(false);
        }
        let eof = |what: &str| Error::new(ErrorKind::UnexpectedEof, format!("stream died {what}"));
        match &mut self.transfer {
            Transfer::Chunked { left } => {
                if *left == 0 {
                    let mut size_line = String::new();
                    if self.reader.read_line(&mut size_line)? == 0 {
                        return Err(eof("before a chunk header"));
                    }
                    let size_s = size_line.trim().split(';').next().unwrap_or("").trim();
                    let size = usize::from_str_radix(size_s, 16).map_err(|_| {
                        Error::new(ErrorKind::InvalidData, format!("bad chunk size {size_line:?}"))
                    })?;
                    if size == 0 {
                        // consume the trailing CRLF after the zero chunk
                        let mut trail = String::new();
                        let _ = self.reader.read_line(&mut trail);
                        self.done = true;
                        return Ok(false);
                    }
                    *left = size;
                }
                let want = (*left).min(16 * 1024);
                let start = self.buf.len();
                self.buf.resize(start + want, 0);
                let n = self.reader.read(&mut self.buf[start..])?;
                self.buf.truncate(start + n);
                if n == 0 {
                    return Err(eof("inside a chunk"));
                }
                *left -= n;
                if *left == 0 {
                    let mut crlf = [0u8; 2];
                    self.reader.read_exact(&mut crlf).map_err(|_| eof("at a chunk boundary"))?;
                }
                Ok(true)
            }
            Transfer::Length { left } => {
                if *left == 0 {
                    self.done = true;
                    return Ok(false);
                }
                let want = (*left).min(16 * 1024);
                let start = self.buf.len();
                self.buf.resize(start + want, 0);
                let n = self.reader.read(&mut self.buf[start..])?;
                self.buf.truncate(start + n);
                if n == 0 {
                    return Err(eof("mid-body"));
                }
                *left -= n;
                Ok(true)
            }
            Transfer::Eof => {
                let start = self.buf.len();
                self.buf.resize(start + 16 * 1024, 0);
                let n = self.reader.read(&mut self.buf[start..])?;
                self.buf.truncate(start + n);
                if n == 0 {
                    self.done = true;
                    return Ok(false);
                }
                Ok(true)
            }
        }
    }

    /// Next newline-terminated line of the body (the NDJSON event frame),
    /// blocking until the server produces one. `Ok(None)` = the body ended
    /// cleanly; `Err` = transport death mid-stream.
    pub fn next_line(&mut self) -> std::io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let rest = self.buf.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop(); // the \n
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
            }
            if !self.fill()? {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                let line = String::from_utf8_lossy(&self.buf).into_owned();
                self.buf.clear();
                return Ok(Some(line));
            }
        }
    }

    /// Drain the rest of the body (non-streaming consumption of an error
    /// response, or a caller that wants the whole payload at once).
    pub fn read_body(&mut self) -> std::io::Result<Vec<u8>> {
        while self.fill()? {}
        Ok(std::mem::take(&mut self.buf))
    }
}

/// Open a streaming request: returns the response status and an
/// [`HttpStream`] that decodes the body incrementally. `connect` bounds
/// the TCP handshake and request write; `read` bounds each wait for the
/// next body byte (use a generous value — streams legitimately pause
/// between decode steps while the model computes).
pub fn http_request_stream(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
    connect: Duration,
    read: Duration,
) -> Result<(u16, HttpStream)> {
    let connect = connect.max(Duration::from_millis(1));
    let read = read.max(Duration::from_millis(1));
    let mut stream = TcpStream::connect_timeout(&addr, connect)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_write_timeout(Some(connect))?;
    stream.set_read_timeout(Some(read))?;
    write_request_head(&mut stream, addr, method, path, body, extra_headers)?;
    let mut reader = BufReader::new(stream);
    let (status, content_length, chunked) = read_response_head(&mut reader)?;
    Ok((status, HttpStream::new(reader, content_length, chunked)))
}

pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, Vec<u8>)> {
    http_request(addr, "GET", path, &[], &[])
}

/// GET with bounded connect/read/write waits (see [`http_request_timeout`]).
pub fn get_timeout(addr: SocketAddr, path: &str, timeout: Duration) -> Result<(u16, Vec<u8>)> {
    http_request_timeout(addr, "GET", path, &[], &[], timeout)
}

pub fn post(addr: SocketAddr, path: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    http_request(addr, "POST", path, body, &[("Content-Type", "application/json")])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        HttpServer::bind(
            "127.0.0.1:0",
            2,
            Arc::new(|req: Request| {
                if req.path == "/health" {
                    Response::text(200, "ok")
                } else if req.method == "POST" {
                    Response::bytes(200, "application/json", req.body)
                } else {
                    Response::not_found()
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_and_post_round_trip() {
        let srv = echo_server();
        let (status, body) = get(srv.addr(), "/health").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"ok");

        let payload = br#"{"x": [1,2,3]}"#;
        let (status, body) = post(srv.addr(), "/echo", payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);

        let (status, _) = get(srv.addr(), "/missing").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn large_body_round_trip() {
        let srv = echo_server();
        let payload = vec![b'x'; 2_000_000];
        let (status, body) = post(srv.addr(), "/echo", &payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body.len(), payload.len());
    }

    #[test]
    fn concurrent_clients() {
        let srv = echo_server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                std::thread::spawn(move || {
                    let payload = format!("{{\"i\":{i}}}");
                    let (status, body) = post(addr, "/echo", payload.as_bytes()).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(body, payload.as_bytes());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn timeout_requests_round_trip() {
        let srv = echo_server();
        let (status, body) = get_timeout(srv.addr(), "/health", Duration::from_secs(5)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"ok");
        // a refused port fails fast rather than hanging
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let t0 = std::time::Instant::now();
        assert!(get_timeout(dead, "/health", Duration::from_millis(500)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    /// `/stream` emits `count` NDJSON lines as chunks; `/truncate` aborts
    /// after 2 lines without the terminator.
    fn chunk_server() -> HttpServer {
        HttpServer::bind(
            "127.0.0.1:0",
            2,
            Arc::new(|req: Request| {
                let truncate = req.path.starts_with("/truncate");
                let count = 5usize;
                let mut i = 0usize;
                Response::chunked(
                    200,
                    "application/x-ndjson",
                    Box::new(move || {
                        if truncate && i == 2 {
                            return Chunk::Abort;
                        }
                        if i >= count {
                            return Chunk::End;
                        }
                        i += 1;
                        Chunk::Data(format!("{{\"n\":{}}}\n", i - 1).into_bytes())
                    }),
                )
            }),
        )
        .unwrap()
    }

    #[test]
    fn chunked_stream_yields_lines_incrementally() {
        let srv = chunk_server();
        let (status, mut hs) = http_request_stream(
            srv.addr(),
            "GET",
            "/stream",
            &[],
            &[],
            Duration::from_secs(5),
            Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(status, 200);
        let mut lines = Vec::new();
        while let Some(line) = hs.next_line().unwrap() {
            lines.push(line);
        }
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[0], "{\"n\":0}");
        assert_eq!(lines[4], "{\"n\":4}");
    }

    #[test]
    fn chunked_truncation_is_an_error_not_a_clean_end() {
        let srv = chunk_server();
        let (status, mut hs) = http_request_stream(
            srv.addr(),
            "GET",
            "/truncate",
            &[],
            &[],
            Duration::from_secs(5),
            Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(hs.next_line().unwrap().is_some());
        assert!(hs.next_line().unwrap().is_some());
        // the third pull hits the dropped connection: an error, never a
        // silent clean end
        let err = loop {
            match hs.next_line() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("truncation reported as clean end"),
                Err(e) => break e,
            }
        };
        assert!(
            matches!(
                err.kind(),
                std::io::ErrorKind::UnexpectedEof | std::io::ErrorKind::ConnectionReset
            ),
            "{err}"
        );
    }

    #[test]
    fn non_streaming_client_still_reads_chunked_bodies() {
        let srv = chunk_server();
        let (status, body) = get(srv.addr(), "/stream").unwrap();
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        assert_eq!(text.lines().count(), 5);
    }

    #[test]
    fn extra_headers_are_written() {
        let srv = HttpServer::bind(
            "127.0.0.1:0",
            1,
            Arc::new(|_req: Request| {
                Response::text(429, "slow down").with_header("Retry-After", "2")
            }),
        )
        .unwrap();
        // raw client so we can see the header lines themselves
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 429 Too Many Requests"), "{raw}");
        assert!(raw.contains("Retry-After: 2\r\n"), "{raw}");
    }

    #[test]
    fn shutdown_stops_serving() {
        let mut srv = echo_server();
        let addr = srv.addr();
        srv.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(get(addr, "/health").is_err() || get(addr, "/health").unwrap().0 != 200);
    }
}
