//! Minimal HTTP/1.1 server and client over `std::net`.
//!
//! The NDIF frontend is an HTTP service ("the system serializes the
//! intervention graph into a custom JSON format and sends it to NDIF's
//! HTTP server front-end", §B.2). No async stack is available offline, so
//! this is a small, correct, thread-pool-backed HTTP/1.1 implementation:
//! request line + headers + Content-Length bodies, one connection per
//! request (`Connection: close`). That is all the NDIF protocol needs, and
//! it keeps the request path free of hidden allocation or buffering
//! surprises when we profile it (§Perf).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::threadpool::ThreadPool;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body not utf-8")
    }
}

/// An HTTP response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain", body: body.as_bytes().to_vec() }
    }

    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }

    pub fn bad_request(msg: &str) -> Response {
        Response::json(400, format!("{{\"error\":{}}}", crate::json::Json::from(msg)))
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            401 => "Unauthorized",
            404 => "Not Found",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// Read one request from a stream.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();

    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_string();
            let v = v.trim().to_string();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse().context("bad content-length")?;
            }
            headers.push((k, v));
        }
    }
    const MAX_BODY: usize = 256 * 1024 * 1024;
    if content_length > MAX_BODY {
        return Err(anyhow!("body too large: {content_length}"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, headers, body })
}

/// Write a response (and close the connection).
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.status_text(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Handler signature: pure request → response.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync + 'static>;

/// A running HTTP server (accept loop + worker pool). Dropping shuts down.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind to `addr` (use port 0 for ephemeral) and serve on `workers`
    /// pool threads.
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("nnscope-http-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            let handler = Arc::clone(&handler);
                            pool.execute(move || {
                                let resp = match read_request(&mut stream) {
                                    Ok(req) => handler(req),
                                    Err(e) => Response::bad_request(&e.to_string()),
                                };
                                let _ = write_response(&mut stream, &resp);
                            });
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Perform one HTTP request; returns (status, body). No socket timeouts:
/// the call blocks as long as the server holds the response (long-poll
/// clients rely on this).
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> Result<(u16, Vec<u8>)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    request_on(stream, addr, method, path, body, extra_headers)
}

/// Like [`http_request`] but with a connect deadline and socket read/write
/// timeouts, so a hung peer cannot block the caller forever (the
/// coordinator's proxy and probe paths). The read timeout must exceed any
/// server-side long-poll hold the request asks for.
pub fn http_request_timeout(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
    timeout: Duration,
) -> Result<(u16, Vec<u8>)> {
    http_request_deadlines(addr, method, path, body, extra_headers, timeout, timeout)
}

/// [`http_request_timeout`] with separate bounds: `connect` caps the TCP
/// handshake and request writes (detects unreachable peers fast), `read`
/// caps waiting for the response (longer for endpoints that legitimately
/// hold, e.g. a synchronous session run).
pub fn http_request_deadlines(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
    connect: Duration,
    read: Duration,
) -> Result<(u16, Vec<u8>)> {
    let connect = connect.max(Duration::from_millis(1)); // zero would disable
    let read = read.max(Duration::from_millis(1));
    let stream = TcpStream::connect_timeout(&addr, connect)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_write_timeout(Some(connect))?;
    stream.set_read_timeout(Some(read))?;
    request_on(stream, addr, method, path, body, extra_headers)
}

fn request_on(
    mut stream: TcpStream,
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> Result<(u16, Vec<u8>)> {
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?
        .parse()
        .context("bad status code")?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = Some(v.trim().parse().context("bad content-length")?);
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body)?;
        }
        None => {
            reader.read_to_end(&mut body)?;
        }
    }
    Ok((status, body))
}

pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, Vec<u8>)> {
    http_request(addr, "GET", path, &[], &[])
}

/// GET with bounded connect/read/write waits (see [`http_request_timeout`]).
pub fn get_timeout(addr: SocketAddr, path: &str, timeout: Duration) -> Result<(u16, Vec<u8>)> {
    http_request_timeout(addr, "GET", path, &[], &[], timeout)
}

pub fn post(addr: SocketAddr, path: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    http_request(addr, "POST", path, body, &[("Content-Type", "application/json")])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        HttpServer::bind(
            "127.0.0.1:0",
            2,
            Arc::new(|req: Request| {
                if req.path == "/health" {
                    Response::text(200, "ok")
                } else if req.method == "POST" {
                    Response { status: 200, content_type: "application/json", body: req.body }
                } else {
                    Response::not_found()
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_and_post_round_trip() {
        let srv = echo_server();
        let (status, body) = get(srv.addr(), "/health").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"ok");

        let payload = br#"{"x": [1,2,3]}"#;
        let (status, body) = post(srv.addr(), "/echo", payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);

        let (status, _) = get(srv.addr(), "/missing").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn large_body_round_trip() {
        let srv = echo_server();
        let payload = vec![b'x'; 2_000_000];
        let (status, body) = post(srv.addr(), "/echo", &payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body.len(), payload.len());
    }

    #[test]
    fn concurrent_clients() {
        let srv = echo_server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                std::thread::spawn(move || {
                    let payload = format!("{{\"i\":{i}}}");
                    let (status, body) = post(addr, "/echo", payload.as_bytes()).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(body, payload.as_bytes());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn timeout_requests_round_trip() {
        let srv = echo_server();
        let (status, body) = get_timeout(srv.addr(), "/health", Duration::from_secs(5)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"ok");
        // a refused port fails fast rather than hanging
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let t0 = std::time::Instant::now();
        assert!(get_timeout(dead, "/health", Duration::from_millis(500)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn shutdown_stops_serving() {
        let mut srv = echo_server();
        let addr = srv.addr();
        srv.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(get(addr, "/health").is_err() || get(addr, "/health").unwrap().0 != 200);
    }
}
