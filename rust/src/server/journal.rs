//! Append-only result journal: crash durability for the object store.
//!
//! The coordinator already gives the fabric at-least-once *execution*
//! (failed dispatches are retried on another replica); what it cannot do
//! is resurrect a result that finished on a replica that then died before
//! the client picked it up. With `--data-dir` set, every completed result
//! (`Ready`/`Failed`) and every eviction is appended to a journal; a
//! restarted replica replays it and the surviving completed results are
//! served as if the crash never happened — at-least-once execution below,
//! exactly-once pickup above.
//!
//! Design choices, in order of how much they matter:
//! * **Append-only with explicit evictions.** The store's lifecycle is
//!   write-once / read-once / expire; journaling `evict` records instead
//!   of rewriting state keeps the hot path a single sequential append.
//! * **Corrupt-tail truncation, not failure.** A crash mid-append leaves a
//!   torn record at the tail; replay verifies each record's length frame
//!   and FNV checksum and truncates at the first bad byte. Everything
//!   before the tear survives; a torn journal is never fatal.
//! * **Batched fsync.** Appends always flush to the OS (surviving process
//!   death); `fsync` is amortized over [`Journal::fsync_every`] records,
//!   bounding what a *machine* crash can lose to the last batch.
//! * **Compaction on evict.** When dead records outnumber live ones the
//!   journal is rewritten from the live set into a temp file and atomically
//!   renamed into place, so the file tracks the working set, not history.
//!
//! `Pending` entries are deliberately not journaled: an unexecuted request
//! is the coordinator's to retry, not the replica's to resurrect.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::{parse, Json};
use crate::server::store::Entry;
use crate::util::failpoint::{self, FailAction};

/// First byte of every record frame — a fixed sentinel so replay can tell
/// "next record" from "garbage tail" without heuristics.
const MAGIC: u8 = 0xA7;
/// Frame header: magic byte + u32 payload length + u32 FNV-1a checksum.
const HEADER: usize = 1 + 4 + 4;
/// Upper bound on a sane payload; anything larger is a corrupt length
/// field, not a record.
const MAX_PAYLOAD: usize = 256 * 1024 * 1024;

fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// One journal record (the durable subset of the store's lifecycle).
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    Ready { id: String, json: String },
    Failed { id: String, err: String },
    Evict { id: String },
}

impl Record {
    fn to_payload(&self) -> Vec<u8> {
        let j = match self {
            Record::Ready { id, json } => Json::obj(vec![
                ("op", Json::from("r")),
                ("id", Json::from(id.as_str())),
                ("v", Json::from(json.as_str())),
            ]),
            Record::Failed { id, err } => Json::obj(vec![
                ("op", Json::from("f")),
                ("id", Json::from(id.as_str())),
                ("v", Json::from(err.as_str())),
            ]),
            Record::Evict { id } => Json::obj(vec![
                ("op", Json::from("e")),
                ("id", Json::from(id.as_str())),
            ]),
        };
        j.to_string().into_bytes()
    }

    fn from_payload(bytes: &[u8]) -> Option<Record> {
        let text = std::str::from_utf8(bytes).ok()?;
        let j = parse(text).ok()?;
        let id = j.get("id").as_str()?.to_string();
        match j.get("op").as_str()? {
            "r" => Some(Record::Ready { id, json: j.get("v").as_str()?.to_string() }),
            "f" => Some(Record::Failed { id, err: j.get("v").as_str()?.to_string() }),
            "e" => Some(Record::Evict { id }),
            _ => None,
        }
    }

    fn frame(&self) -> Vec<u8> {
        let payload = self.to_payload();
        let mut out = Vec::with_capacity(HEADER + payload.len());
        out.push(MAGIC);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&fnv1a32(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

/// What replay recovered (surfaced in the server log and obs counters).
#[derive(Debug, Default)]
pub struct ReplayReport {
    /// Live completed entries after applying the whole journal.
    pub entries: Vec<(String, Entry)>,
    /// Total well-formed records read (including evictions).
    pub records: usize,
    /// Bytes cut off the tail because a record frame was torn or corrupt.
    pub truncated_bytes: u64,
}

/// Append-only, checksummed, compacting result journal.
pub struct Journal {
    path: PathBuf,
    file: File,
    /// fsync after this many appends (1 = every append; durability vs
    /// throughput knob).
    pub fsync_every: u32,
    unsynced: u32,
    live: usize,
    dead: usize,
}

impl Journal {
    /// Open (creating if absent) and replay the journal at `path`. A torn
    /// or corrupt tail is truncated in place; replay itself never fails on
    /// record content, only on I/O.
    pub fn open(path: &Path) -> Result<(Journal, ReplayReport)> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create journal dir {dir:?}"))?;
            }
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .with_context(|| format!("open journal {path:?}"))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes).context("read journal")?;

        let mut report = ReplayReport::default();
        let mut live: std::collections::HashMap<String, Entry> = std::collections::HashMap::new();
        let mut order: Vec<String> = Vec::new();
        let mut off = 0usize;
        loop {
            let rest = &bytes[off..];
            if rest.is_empty() {
                break;
            }
            let Some(rec) = decode_frame(rest) else {
                // torn or corrupt tail: cut it off and stop
                report.truncated_bytes = (bytes.len() - off) as u64;
                file.set_len(off as u64).context("truncate torn journal tail")?;
                break;
            };
            let (rec, frame_len) = rec;
            report.records += 1;
            match rec {
                Record::Ready { id, json } => {
                    if live.insert(id.clone(), Entry::Ready(json)).is_none() {
                        order.push(id);
                    }
                }
                Record::Failed { id, err } => {
                    if live.insert(id.clone(), Entry::Failed(err)).is_none() {
                        order.push(id);
                    }
                }
                Record::Evict { id } => {
                    live.remove(&id);
                }
            }
            off += frame_len;
        }
        for id in order {
            if let Some(e) = live.remove(&id) {
                report.entries.push((id, e));
            }
        }

        file.seek(SeekFrom::End(0)).context("seek journal end")?;
        let n_live = report.entries.len();
        let journal = Journal {
            path: path.to_path_buf(),
            file,
            fsync_every: 8,
            unsynced: 0,
            live: n_live,
            dead: report.records.saturating_sub(n_live),
        };
        Ok((journal, report))
    }

    /// Append one record. Failpoint site `journal.append` can fail the
    /// append, drop it silently, delay it, or tear it mid-frame.
    pub fn append(&mut self, rec: &Record) -> Result<()> {
        let frame = rec.frame();
        match failpoint::hit("journal.append") {
            Some(FailAction::Error(msg)) => anyhow::bail!("injected journal fault: {msg}"),
            Some(FailAction::Skip) => return Ok(()),
            Some(FailAction::Delay(d)) => std::thread::sleep(d),
            Some(FailAction::Truncate(n)) => {
                let torn = &frame[..n.min(frame.len())];
                self.file.write_all(torn).context("journal torn write")?;
                self.file.flush().ok();
                anyhow::bail!("injected journal fault: torn write after {} bytes", torn.len());
            }
            None => {}
        }
        self.file.write_all(&frame).context("journal append")?;
        match rec {
            Record::Evict { .. } => {
                self.live = self.live.saturating_sub(1);
                self.dead += 1;
            }
            _ => self.live += 1,
        }
        self.unsynced += 1;
        if self.unsynced >= self.fsync_every {
            self.sync()?;
        }
        Ok(())
    }

    /// Force the batched fsync now.
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_all().context("journal fsync")?;
        self.unsynced = 0;
        Ok(())
    }

    /// Compaction trigger: dead records dominate live ones (and the file
    /// is past trivial size, so short-lived stores never bother).
    pub fn should_compact(&self) -> bool {
        self.dead > 64 && self.dead > 2 * self.live
    }

    /// Rewrite the journal to exactly `entries` (the store's current
    /// completed set): fresh records into a temp file, fsync, atomic
    /// rename over the old journal.
    pub fn compact(&mut self, entries: &[(String, Entry)]) -> Result<()> {
        let tmp = self.path.with_extension("journal.tmp");
        let mut out = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut n = 0usize;
        for (id, entry) in entries {
            let rec = match entry {
                Entry::Ready(json) => Record::Ready { id: id.clone(), json: json.clone() },
                Entry::Failed(err) => Record::Failed { id: id.clone(), err: err.clone() },
                Entry::Pending => continue,
            };
            out.write_all(&rec.frame()).context("compact write")?;
            n += 1;
        }
        out.sync_all().context("compact fsync")?;
        drop(out);
        std::fs::rename(&tmp, &self.path).context("compact rename")?;
        self.file = OpenOptions::new()
            .read(true)
            .append(true)
            .open(&self.path)
            .context("reopen compacted journal")?;
        self.live = n;
        self.dead = 0;
        self.unsynced = 0;
        Ok(())
    }

    /// Current journal size in bytes (tests, metrics).
    pub fn size_bytes(&self) -> u64 {
        std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0)
    }
}

/// Decode one frame from the head of `bytes`; `None` means torn/corrupt.
fn decode_frame(bytes: &[u8]) -> Option<(Record, usize)> {
    if bytes.len() < HEADER || bytes[0] != MAGIC {
        return None;
    }
    let len = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
    let ck = u32::from_le_bytes(bytes[5..9].try_into().unwrap());
    if len > MAX_PAYLOAD || bytes.len() < HEADER + len {
        return None;
    }
    let payload = &bytes[HEADER..HEADER + len];
    if fnv1a32(payload) != ck {
        return None;
    }
    Record::from_payload(payload).map(|r| (r, HEADER + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nnscope-journal-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_replay_round_trip() {
        let dir = tmpdir("roundtrip");
        let path = dir.join("results.journal");
        {
            let (mut j, rep) = Journal::open(&path).unwrap();
            assert_eq!(rep.records, 0);
            j.append(&Record::Ready { id: "r-1".into(), json: "{\"a\":1}".into() }).unwrap();
            j.append(&Record::Failed { id: "r-2".into(), err: "boom".into() }).unwrap();
            j.append(&Record::Ready { id: "r-3".into(), json: "{}".into() }).unwrap();
            j.append(&Record::Evict { id: "r-1".into() }).unwrap();
            j.sync().unwrap();
        }
        let (_j, rep) = Journal::open(&path).unwrap();
        assert_eq!(rep.records, 4);
        assert_eq!(rep.truncated_bytes, 0);
        let mut ids: Vec<&str> = rep.entries.iter().map(|(id, _)| id.as_str()).collect();
        ids.sort();
        assert_eq!(ids, vec!["r-2", "r-3"]);
        assert!(rep
            .entries
            .iter()
            .any(|(id, e)| id == "r-2" && *e == Entry::Failed("boom".into())));
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let dir = tmpdir("torn");
        let path = dir.join("results.journal");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.append(&Record::Ready { id: "ok-1".into(), json: "{}".into() }).unwrap();
            j.append(&Record::Ready { id: "ok-2".into(), json: "{}".into() }).unwrap();
            j.sync().unwrap();
        }
        // simulate a crash mid-append: garbage + half a frame at the tail
        let good_len = std::fs::metadata(&path).unwrap().len();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        let torn = &Record::Evict { id: "never".into() }.frame()[..6];
        f.write_all(torn).unwrap();
        drop(f);

        let (_j, rep) = Journal::open(&path).unwrap();
        assert_eq!(rep.entries.len(), 2, "records before the tear survive");
        assert_eq!(rep.truncated_bytes, 6);
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            good_len,
            "tail physically truncated"
        );
        // and the journal is appendable again after truncation
        let (mut j, _) = Journal::open(&path).unwrap();
        j.append(&Record::Ready { id: "ok-3".into(), json: "{}".into() }).unwrap();
        j.sync().unwrap();
        let (_j, rep) = Journal::open(&path).unwrap();
        assert_eq!(rep.entries.len(), 3);
    }

    #[test]
    fn corrupt_checksum_truncates_from_bad_record() {
        let dir = tmpdir("cksum");
        let path = dir.join("results.journal");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.append(&Record::Ready { id: "a".into(), json: "{}".into() }).unwrap();
            j.append(&Record::Ready { id: "b".into(), json: "{}".into() }).unwrap();
            j.sync().unwrap();
        }
        // flip a byte inside the second record's payload
        let mut bytes = std::fs::read(&path).unwrap();
        let first_len = {
            let l = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
            HEADER + l
        };
        let target = first_len + HEADER + 2;
        bytes[target] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let (_j, rep) = Journal::open(&path).unwrap();
        assert_eq!(rep.entries.len(), 1);
        assert_eq!(rep.entries[0].0, "a");
        assert!(rep.truncated_bytes > 0);
    }

    #[test]
    fn compaction_drops_dead_records() {
        let dir = tmpdir("compact");
        let path = dir.join("results.journal");
        let (mut j, _) = Journal::open(&path).unwrap();
        for i in 0..200 {
            j.append(&Record::Ready { id: format!("r-{i}"), json: "{}".into() }).unwrap();
            j.append(&Record::Evict { id: format!("r-{i}") }).unwrap();
        }
        j.append(&Record::Ready { id: "keep".into(), json: "{\"k\":1}".into() }).unwrap();
        j.sync().unwrap();
        assert!(j.should_compact());
        let before = j.size_bytes();
        j.compact(&[("keep".into(), Entry::Ready("{\"k\":1}".into()))]).unwrap();
        assert!(j.size_bytes() < before / 10, "compaction must shrink the file");
        assert!(!j.should_compact());
        let (_j, rep) = Journal::open(&path).unwrap();
        assert_eq!(rep.entries, vec![("keep".into(), Entry::Ready("{\"k\":1}".into()))]);
    }

    #[test]
    fn injected_torn_write_reproduces_crash_mid_journal() {
        use crate::util::failpoint::{Armed, FailAction, Spec};
        let dir = tmpdir("failpoint");
        let path = dir.join("results.journal");
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.append(&Record::Ready { id: "done".into(), json: "{}".into() }).unwrap();
            let _g = Armed::new("journal.append", Spec::nth(0, FailAction::Truncate(7)));
            let err = j
                .append(&Record::Ready { id: "torn".into(), json: "{}".into() })
                .unwrap_err();
            assert!(err.to_string().contains("torn"), "{err}");
            j.sync().unwrap();
        }
        let (_j, rep) = Journal::open(&path).unwrap();
        assert_eq!(rep.entries.len(), 1, "record before the tear survives");
        assert_eq!(rep.entries[0].0, "done");
        assert_eq!(rep.truncated_bytes, 7);
    }
}
