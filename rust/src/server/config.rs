//! Server configuration files.
//!
//! A deployable service needs declarative configuration; `nnscope serve
//! --config deploy.json` loads one of these:
//!
//! ```json
//! {
//!   "addr": "0.0.0.0:7757",
//!   "workers": 16,
//!   "models": ["llama8b-sim", "opt-13b-sim"],
//!   "artifacts": "/srv/nnscope/artifacts",
//!   "cotenancy": { "mode": "parallel", "max_merge": 8 },
//!   "auth": { "llama8b-sim": ["token-a", "token-b"] }
//! }
//! ```
//!
//! Every field is optional; omissions fall back to [`NdifConfig::local`]
//! defaults (ephemeral port, sequential co-tenancy, open access).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::json::{parse, Json};
use crate::scheduler::CoTenancy;

use super::api::NdifConfig;

/// Parse a config from JSON text.
pub fn from_json_text(text: &str) -> Result<NdifConfig> {
    let j = parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
    from_json(&j)
}

/// Load a config from a file.
pub fn from_file(path: &Path) -> Result<NdifConfig> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read config {path:?}"))?;
    from_json_text(&text)
}

fn from_json(j: &Json) -> Result<NdifConfig> {
    let mut cfg = NdifConfig::local(&[]);
    if let Some(addr) = j.get("addr").as_str() {
        cfg.addr = addr.to_string();
    }
    if let Some(w) = j.get("workers").as_usize() {
        cfg.workers = w.max(1);
    }
    if let Some(models) = j.get("models").as_array() {
        cfg.models = models
            .iter()
            .map(|m| {
                m.as_str()
                    .map(String::from)
                    .ok_or_else(|| anyhow!("models entries must be strings"))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(dir) = j.get("artifacts").as_str() {
        cfg.artifacts = dir.into();
    }
    let cot = j.get("cotenancy");
    if !cot.is_null() {
        cfg.cotenancy = match cot.get("mode").as_str() {
            Some("sequential") | None => CoTenancy::Sequential,
            Some("parallel") => CoTenancy::Parallel {
                max_merge: cot.get("max_merge").as_usize().unwrap_or(8),
            },
            Some(other) => return Err(anyhow!("unknown cotenancy mode '{other}'")),
        };
    }
    if let Some(auth) = j.get("auth").as_object() {
        let mut map = HashMap::new();
        for (model, tokens) in auth {
            let toks = tokens
                .as_array()
                .ok_or_else(|| anyhow!("auth.{model} must be a token array"))?
                .iter()
                .map(|t| {
                    t.as_str()
                        .map(String::from)
                        .ok_or_else(|| anyhow!("auth tokens must be strings"))
                })
                .collect::<Result<Vec<_>>>()?;
            map.insert(model.clone(), toks);
        }
        cfg.auth = map;
    }
    if cfg.models.is_empty() {
        return Err(anyhow!("config must list at least one model"));
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let cfg = from_json_text(
            r#"{
              "addr": "0.0.0.0:7757",
              "workers": 16,
              "models": ["llama8b-sim", "opt-13b-sim"],
              "artifacts": "/srv/a",
              "cotenancy": { "mode": "parallel", "max_merge": 4 },
              "auth": { "llama8b-sim": ["t1", "t2"] }
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:7757");
        assert_eq!(cfg.workers, 16);
        assert_eq!(cfg.models, vec!["llama8b-sim", "opt-13b-sim"]);
        assert_eq!(cfg.artifacts, std::path::PathBuf::from("/srv/a"));
        assert_eq!(cfg.cotenancy, CoTenancy::Parallel { max_merge: 4 });
        assert_eq!(cfg.auth["llama8b-sim"], vec!["t1", "t2"]);
    }

    #[test]
    fn minimal_config_gets_defaults() {
        let cfg = from_json_text(r#"{"models": ["tiny-sim"]}"#).unwrap();
        assert_eq!(cfg.cotenancy, CoTenancy::Sequential);
        assert!(cfg.auth.is_empty());
        assert!(cfg.workers >= 1);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(from_json_text("{}").is_err()); // no models
        assert!(from_json_text(r#"{"models": ["m"], "cotenancy": {"mode": "magic"}}"#).is_err());
        assert!(from_json_text(r#"{"models": [3]}"#).is_err());
        assert!(from_json_text("not json").is_err());
    }
}
