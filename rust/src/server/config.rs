//! Server configuration files.
//!
//! A deployable service needs declarative configuration; `nnscope serve
//! --config deploy.json` loads one of these:
//!
//! ```json
//! {
//!   "addr": "0.0.0.0:7757",
//!   "workers": 16,
//!   "models": ["llama8b-sim", "opt-13b-sim"],
//!   "artifacts": "/srv/nnscope/artifacts",
//!   "cotenancy": { "mode": "parallel", "max_merge": 8 },
//!   "auth": { "llama8b-sim": ["token-a", "token-b"] },
//!   "coordinator": "10.0.0.1:7788",
//!   "advertise": "10.0.0.5:7757",
//!   "heartbeat_ms": 250,
//!   "link_latency_s": 0.010,
//!   "optimize": true
//! }
//! ```
//!
//! Every field is optional; omissions fall back to [`NdifConfig::local`]
//! defaults (ephemeral port, sequential co-tenancy, open access,
//! standalone — no coordinator).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::json::{parse, Json};
use crate::scheduler::CoTenancy;

use super::api::NdifConfig;

/// Parse a config from JSON text.
pub fn from_json_text(text: &str) -> Result<NdifConfig> {
    let j = parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
    from_json(&j)
}

/// Load a config from a file.
pub fn from_file(path: &Path) -> Result<NdifConfig> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read config {path:?}"))?;
    from_json_text(&text)
}

fn from_json(j: &Json) -> Result<NdifConfig> {
    let mut cfg = NdifConfig::local(&[]);
    if let Some(addr) = j.get("addr").as_str() {
        cfg.addr = addr.to_string();
    }
    if let Some(w) = j.get("workers").as_usize() {
        cfg.workers = w.max(1);
    }
    if let Some(models) = j.get("models").as_array() {
        cfg.models = models
            .iter()
            .map(|m| {
                m.as_str()
                    .map(String::from)
                    .ok_or_else(|| anyhow!("models entries must be strings"))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(dir) = j.get("artifacts").as_str() {
        cfg.artifacts = dir.into();
    }
    let cot = j.get("cotenancy");
    if !cot.is_null() {
        cfg.cotenancy = match cot.get("mode").as_str() {
            Some("sequential") | None => CoTenancy::Sequential,
            Some("parallel") => CoTenancy::Parallel {
                max_merge: cot.get("max_merge").as_usize().unwrap_or(8),
            },
            Some(other) => return Err(anyhow!("unknown cotenancy mode '{other}'")),
        };
    }
    if let Some(auth) = j.get("auth").as_object() {
        let mut map = HashMap::new();
        for (model, tokens) in auth {
            let toks = tokens
                .as_array()
                .ok_or_else(|| anyhow!("auth.{model} must be a token array"))?
                .iter()
                .map(|t| {
                    t.as_str()
                        .map(String::from)
                        .ok_or_else(|| anyhow!("auth tokens must be strings"))
                })
                .collect::<Result<Vec<_>>>()?;
            map.insert(model.clone(), toks);
        }
        cfg.auth = map;
    }
    if let Some(c) = j.get("coordinator").as_str() {
        cfg.coordinator = Some(c.to_string());
    }
    if let Some(a) = j.get("advertise").as_str() {
        cfg.advertise = Some(a.to_string());
    }
    if let Some(ms) = j.get("heartbeat_ms").as_i64() {
        cfg.heartbeat = std::time::Duration::from_millis(ms.max(1) as u64);
    }
    if let Some(l) = j.get("link_latency_s").as_f64() {
        cfg.link_latency_s = l;
    }
    if let Some(o) = j.get("optimize").as_bool() {
        cfg.optimize = o;
    }
    if let Some(p) = j.get("plan_cache").as_bool() {
        cfg.plan_cache = p;
    }
    if let Some(n) = j.get("plan_cache_cap").as_usize() {
        cfg.plan_cache_cap = n.max(1);
    }
    if let Some(o) = j.get("obs").as_bool() {
        cfg.obs = o;
    }
    if let Some(n) = j.get("trace_ring").as_usize() {
        cfg.trace_ring = n;
    }
    if let Some(n) = j.get("profile_ring").as_usize() {
        cfg.profile_ring = n;
    }
    if let Some(n) = j.get("profile_sample_n").as_usize() {
        cfg.profile_sample_n = n;
    }
    if let Some(d) = j.get("data_dir").as_str() {
        cfg.data_dir = Some(d.into());
    }
    let rl = j.get("rate_limit");
    if !rl.is_null() {
        let per_s = rl
            .get("per_s")
            .as_f64()
            .ok_or_else(|| anyhow!("rate_limit.per_s must be a number"))?;
        if per_s <= 0.0 {
            return Err(anyhow!("rate_limit.per_s must be positive"));
        }
        let burst = rl.get("burst").as_f64().unwrap_or(per_s.max(1.0));
        cfg.rate_limit = Some(crate::server::admission::RateLimit::new(per_s, burst));
    }
    if let Some(n) = j.get("tenant_queue_cap").as_usize() {
        cfg.tenant_queue_cap = n.max(1);
    }
    let shed = j.get("shed");
    if !shed.is_null() {
        let anon = shed
            .get("anon_above")
            .as_usize()
            .ok_or_else(|| anyhow!("shed.anon_above must be an integer"))?;
        let all = shed.get("all_above").as_usize().unwrap_or(anon.saturating_mul(2));
        cfg.shed = crate::server::admission::ShedPolicy {
            shed_anon_above: anon,
            shed_all_above: all,
        };
    }
    if cfg.models.is_empty() {
        return Err(anyhow!("config must list at least one model"));
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let cfg = from_json_text(
            r#"{
              "addr": "0.0.0.0:7757",
              "workers": 16,
              "models": ["llama8b-sim", "opt-13b-sim"],
              "artifacts": "/srv/a",
              "cotenancy": { "mode": "parallel", "max_merge": 4 },
              "auth": { "llama8b-sim": ["t1", "t2"] },
              "coordinator": "10.0.0.1:7788",
              "advertise": "10.0.0.5:7757",
              "heartbeat_ms": 100,
              "link_latency_s": 0.01
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:7757");
        assert_eq!(cfg.workers, 16);
        assert_eq!(cfg.models, vec!["llama8b-sim", "opt-13b-sim"]);
        assert_eq!(cfg.artifacts, std::path::PathBuf::from("/srv/a"));
        assert_eq!(cfg.cotenancy, CoTenancy::Parallel { max_merge: 4 });
        assert_eq!(cfg.auth["llama8b-sim"], vec!["t1", "t2"]);
        assert_eq!(cfg.coordinator.as_deref(), Some("10.0.0.1:7788"));
        assert_eq!(cfg.advertise.as_deref(), Some("10.0.0.5:7757"));
        assert_eq!(cfg.heartbeat, std::time::Duration::from_millis(100));
        assert!((cfg.link_latency_s - 0.01).abs() < 1e-12);
    }

    #[test]
    fn minimal_config_gets_defaults() {
        let cfg = from_json_text(r#"{"models": ["tiny-sim"]}"#).unwrap();
        assert_eq!(cfg.cotenancy, CoTenancy::Sequential);
        assert!(cfg.auth.is_empty());
        assert!(cfg.workers >= 1);
        assert!(cfg.coordinator.is_none());
        assert!(cfg.advertise.is_none());
        assert!(cfg.optimize, "the admission compiler is on by default");
    }

    #[test]
    fn optimize_toggle_parses() {
        let cfg = from_json_text(r#"{"models": ["m"], "optimize": false}"#).unwrap();
        assert!(!cfg.optimize);
        let cfg = from_json_text(r#"{"models": ["m"], "optimize": true}"#).unwrap();
        assert!(cfg.optimize);
    }

    #[test]
    fn plan_cache_knobs_parse() {
        let cfg = from_json_text(r#"{"models": ["m"]}"#).unwrap();
        assert!(cfg.plan_cache, "the plan cache is on by default");
        assert_eq!(cfg.plan_cache_cap, 256);
        let cfg = from_json_text(
            r#"{"models": ["m"], "plan_cache": false, "plan_cache_cap": 16}"#,
        )
        .unwrap();
        assert!(!cfg.plan_cache);
        assert_eq!(cfg.plan_cache_cap, 16);
        // a zero cap clamps to 1 rather than disabling by accident
        let cfg = from_json_text(r#"{"models": ["m"], "plan_cache_cap": 0}"#).unwrap();
        assert_eq!(cfg.plan_cache_cap, 1);
    }

    #[test]
    fn obs_toggles_parse() {
        let cfg = from_json_text(r#"{"models": ["m"]}"#).unwrap();
        assert!(cfg.obs, "observability is on by default");
        assert_eq!(cfg.trace_ring, 256);
        assert_eq!(cfg.profile_ring, 64);
        assert_eq!(cfg.profile_sample_n, 0, "unsolicited profiling off by default");
        let cfg = from_json_text(
            r#"{"models": ["m"], "obs": false, "trace_ring": 16,
                "profile_ring": 4, "profile_sample_n": 100}"#,
        )
        .unwrap();
        assert!(!cfg.obs);
        assert_eq!(cfg.trace_ring, 16);
        assert_eq!(cfg.profile_ring, 4);
        assert_eq!(cfg.profile_sample_n, 100);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(from_json_text("{}").is_err()); // no models
        assert!(from_json_text(r#"{"models": ["m"], "cotenancy": {"mode": "magic"}}"#).is_err());
        assert!(from_json_text(r#"{"models": [3]}"#).is_err());
        assert!(from_json_text("not json").is_err());
    }

    #[test]
    fn fault_tolerance_knobs_parse() {
        let cfg = from_json_text(
            r#"{
              "models": ["m"],
              "data_dir": "/srv/nnscope/data",
              "rate_limit": { "per_s": 50.0, "burst": 100.0 },
              "tenant_queue_cap": 32,
              "shed": { "anon_above": 64, "all_above": 256 }
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.data_dir, Some(std::path::PathBuf::from("/srv/nnscope/data")));
        let rl = cfg.rate_limit.unwrap();
        assert!((rl.per_s - 50.0).abs() < 1e-12);
        assert!((rl.burst - 100.0).abs() < 1e-12);
        assert_eq!(cfg.tenant_queue_cap, 32);
        assert_eq!(cfg.shed.shed_anon_above, 64);
        assert_eq!(cfg.shed.shed_all_above, 256);
    }

    #[test]
    fn fault_tolerance_knobs_default_off() {
        let cfg = from_json_text(r#"{"models": ["m"]}"#).unwrap();
        assert!(cfg.data_dir.is_none());
        assert!(cfg.rate_limit.is_none());
        assert_eq!(cfg.tenant_queue_cap, usize::MAX);
        assert_eq!(cfg.shed, crate::server::admission::ShedPolicy::disabled());
    }

    #[test]
    fn rate_limit_defaults_burst_and_rejects_nonpositive() {
        let cfg =
            from_json_text(r#"{"models": ["m"], "rate_limit": {"per_s": 5.0}}"#).unwrap();
        let rl = cfg.rate_limit.unwrap();
        assert!((rl.burst - 5.0).abs() < 1e-12, "burst defaults to per_s");
        assert!(
            from_json_text(r#"{"models": ["m"], "rate_limit": {"per_s": 0.0}}"#).is_err()
        );
        assert!(from_json_text(r#"{"models": ["m"], "rate_limit": {}}"#).is_err());
    }

    #[test]
    fn shed_all_above_defaults_to_double_anon() {
        let cfg =
            from_json_text(r#"{"models": ["m"], "shed": {"anon_above": 10}}"#).unwrap();
        assert_eq!(cfg.shed.shed_anon_above, 10);
        assert_eq!(cfg.shed.shed_all_above, 20);
    }
}
