//! The NDIF server: a multi-tenant intervention-graph inference service
//! (§3.3, §B.2, Fig. 4).
//!
//! Lifecycle of a request (mirroring the paper):
//! 1. client POSTs a serialized intervention graph to `/v1/trace`;
//! 2. the frontend authenticates, parses, validates against the target
//!    model's manifest, registers a pending entry in the object store,
//!    and enqueues the graph on the model's service;
//! 3. the service worker interleaves the graph with (possibly shared)
//!    model execution and deposits saved values in the object store;
//! 4. the client long-polls `/v1/result/<id>` (the websocket-notify +
//!    pull of Fig. 4 collapsed into one bounded blocking GET).
//!
//! Models are preloaded at server start — the architectural property that
//! produces the paper's flat NDIF setup times (Fig. 6a).
//!
//! One `NdifServer` is also one fleet *replica*: with
//! [`NdifConfig::coordinator`] set it self-registers with an L3
//! [`crate::coordinator`] front and pushes load heartbeats, so many
//! deployments of the same model can serve one user population.

pub mod admission;
pub mod api;
pub mod config;
pub mod http;
pub mod journal;
pub mod state;
pub mod store;

pub use admission::{AdmissionControl, Decision, RateLimit, ShedPolicy};
pub use api::{NdifConfig, NdifServer};
pub use state::{SessionStateStore, StateLimits};
