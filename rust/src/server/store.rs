//! The object store (§B.2, Fig. 4): completed intervention results parked
//! for client pickup.
//!
//! In the paper, shard 0 pushes results to the frontend's object store and
//! a websocket notifies the client, which then pulls. Offline we replace
//! the websocket with condvar-backed long-polling: `GET /v1/result/<id>`
//! blocks (bounded) until the entry is ready — same lifecycle, one fewer
//! protocol.
//!
//! Memory is bounded two ways so the map cannot grow forever under
//! sustained traffic:
//! * **eviction on pickup** — [`ObjectStore::wait_outcome`] *takes* a
//!   `Ready`/`Failed` entry out of the map as it hands it to the waiter
//!   (first puller wins; a re-poll of a delivered id is a 404, which was
//!   already the contract when callers removed after reading);
//! * **TTL expiry** — abandoned entries are swept (amortized every
//!   `ttl / 4`) on writes *and* on read/wait paths, so an idle server
//!   that only serves result polls still expires its map: `Ready`/`Failed`
//!   entries older than the TTL, `Pending` entries older than 4× the TTL
//!   (pending work may legitimately sit behind a deep queue; results
//!   nobody ever asked for must still go away).
//!
//! With [`ObjectStore::with_journal`] the store is additionally durable:
//! completed entries and evictions are journaled to disk
//! ([`crate::server::journal`]) and a restarted replica replays the
//! journal, so a crash between job completion and client pickup loses
//! nothing that reached the journal.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::server::journal::{Journal, Record, ReplayReport};
use crate::util::failpoint::{self, FailAction};

/// Entry lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub enum Entry {
    Pending,
    Ready(String),
    Failed(String),
}

struct Slot {
    entry: Entry,
    at: Instant,
}

struct Slots {
    map: HashMap<String, Slot>,
    last_sweep: Instant,
    /// Durability journal; `None` = memory-only (the default).
    journal: Option<Journal>,
}

impl Slots {
    /// Append to the journal, surviving journal faults: durability is
    /// best-effort relative to availability, so a failed append is
    /// reported but never fails the request path.
    fn journal_append(&mut self, rec: Record) {
        if let Some(j) = self.journal.as_mut() {
            if let Err(e) = j.append(&rec) {
                eprintln!("[store] journal append failed (continuing in-memory): {e:#}");
            }
        }
    }

    /// Compact the journal when dead records dominate, rewriting it from
    /// the live completed set.
    fn maybe_compact(&mut self) {
        let Some(j) = self.journal.as_mut() else { return };
        if !j.should_compact() {
            return;
        }
        let live: Vec<(String, Entry)> = self
            .map
            .iter()
            .filter(|(_, s)| !matches!(s.entry, Entry::Pending))
            .map(|(id, s)| (id.clone(), s.entry.clone()))
            .collect();
        if let Err(e) = j.compact(&live) {
            eprintln!("[store] journal compaction failed: {e:#}");
        }
    }
}

/// Thread-safe result store with wakeups, bounded by pickup-eviction and
/// TTL expiry; optionally journaled to disk for crash durability.
pub struct ObjectStore {
    slots: Mutex<Slots>,
    cv: Condvar,
    ttl: Duration,
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectStore {
    /// Default TTL: long enough for the longest legitimate long-poll
    /// cadence, short enough that abandoned results don't accumulate.
    pub const DEFAULT_TTL: Duration = Duration::from_secs(600);

    pub fn new() -> ObjectStore {
        ObjectStore::with_ttl(Self::DEFAULT_TTL)
    }

    pub fn with_ttl(ttl: Duration) -> ObjectStore {
        ObjectStore {
            slots: Mutex::new(Slots {
                map: HashMap::new(),
                last_sweep: Instant::now(),
                journal: None,
            }),
            cv: Condvar::new(),
            ttl,
        }
    }

    /// Durable store: open (or create) the journal at `path`, replay it,
    /// and seed the map with the surviving completed entries. Returns the
    /// replay report so the server can log/count what was recovered.
    pub fn with_journal(ttl: Duration, path: &Path) -> anyhow::Result<(ObjectStore, ReplayReport)> {
        let (journal, report) = Journal::open(path)?;
        let now = Instant::now();
        let map = report
            .entries
            .iter()
            .map(|(id, e)| (id.clone(), Slot { entry: e.clone(), at: now }))
            .collect();
        let store = ObjectStore {
            slots: Mutex::new(Slots { map, last_sweep: now, journal: Some(journal) }),
            cv: Condvar::new(),
            ttl,
        };
        Ok((store, report))
    }

    fn put(&self, id: &str, entry: Entry) {
        // failpoint: lose the write entirely (crash before publishing)
        if matches!(failpoint::hit("store.put"), Some(FailAction::Skip)) {
            return;
        }
        let mut g = self.slots.lock().unwrap();
        self.sweep_locked(&mut g, false);
        match &entry {
            Entry::Ready(json) => {
                g.journal_append(Record::Ready { id: id.to_string(), json: json.clone() })
            }
            Entry::Failed(err) => {
                g.journal_append(Record::Failed { id: id.to_string(), err: err.clone() })
            }
            Entry::Pending => {}
        }
        g.map
            .insert(id.to_string(), Slot { entry, at: Instant::now() });
    }

    /// Sweep at most every `ttl / 4` so reads and writes stay O(1)
    /// amortized; journaling evictions keeps the durable set in step.
    fn sweep_locked(&self, g: &mut Slots, force: bool) {
        if !force && g.last_sweep.elapsed() < self.ttl / 4 {
            return;
        }
        g.last_sweep = Instant::now();
        let ttl = self.ttl;
        let mut expired: Vec<(String, bool)> = Vec::new();
        for (id, s) in g.map.iter() {
            let (limit, completed) = match s.entry {
                Entry::Pending => (ttl * 4, false),
                _ => (ttl, true),
            };
            if s.at.elapsed() > limit {
                expired.push((id.clone(), completed));
            }
        }
        for (id, completed) in expired {
            g.map.remove(&id);
            if completed {
                g.journal_append(Record::Evict { id });
            }
        }
        g.maybe_compact();
    }

    /// Register a pending request id.
    pub fn put_pending(&self, id: &str) {
        self.put(id, Entry::Pending);
    }

    pub fn put_ready(&self, id: &str, json: String) {
        self.put(id, Entry::Ready(json));
        self.cv.notify_all();
    }

    pub fn put_failed(&self, id: &str, err: &str) {
        self.put(id, Entry::Failed(err.to_string()));
        self.cv.notify_all();
    }

    /// Current state without blocking (None = unknown id). Does not evict
    /// the looked-up entry, but does run the amortized TTL sweep — an
    /// idle server that only serves reads must still expire its map.
    pub fn peek(&self, id: &str) -> Option<Entry> {
        let mut g = self.slots.lock().unwrap();
        self.sweep_locked(&mut g, false);
        g.map.get(id).map(|s| s.entry.clone())
    }

    /// Block until the entry leaves Pending or the timeout passes,
    /// **taking** the completed entry out of the store (eviction on
    /// pickup). Returns None on unknown id or timeout-while-pending.
    pub fn wait_outcome(&self, id: &str, timeout: Duration) -> Option<Result<String, String>> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.slots.lock().unwrap();
        self.sweep_locked(&mut guard, false);
        loop {
            match guard.map.get(id).map(|s| &s.entry) {
                None => return None,
                Some(Entry::Ready(_) | Entry::Failed(_)) => {
                    // journal the eviction before handing the payload out:
                    // once delivered, a replayed journal must not
                    // resurrect it (exactly-once pickup)
                    guard.journal_append(Record::Evict { id: id.to_string() });
                    let slot = guard.map.remove(id).expect("presence checked above");
                    guard.maybe_compact();
                    return Some(match slot.entry {
                        Entry::Ready(s) => Ok(s),
                        Entry::Failed(e) => Err(e),
                        Entry::Pending => unreachable!("matched completed above"),
                    });
                }
                Some(Entry::Pending) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                    guard = g;
                    self.sweep_locked(&mut guard, false);
                }
            }
        }
    }

    /// Like [`ObjectStore::wait_outcome`] but only for success payloads.
    pub fn wait_ready(&self, id: &str, timeout: Duration) -> Option<String> {
        match self.wait_outcome(id, timeout) {
            Some(Ok(s)) => Some(s),
            _ => None,
        }
    }

    /// Remove an entry regardless of state (cancellation paths).
    pub fn remove(&self, id: &str) -> Option<Entry> {
        let mut g = self.slots.lock().unwrap();
        let removed = g.map.remove(id).map(|s| s.entry);
        if matches!(removed, Some(Entry::Ready(_) | Entry::Failed(_))) {
            g.journal_append(Record::Evict { id: id.to_string() });
        }
        removed
    }

    /// Force-expire overdue entries now (tests); returns how many remain.
    pub fn sweep_now(&self) -> usize {
        let mut g = self.slots.lock().unwrap();
        self.sweep_locked(&mut g, true);
        g.map.len()
    }

    /// Flush the journal's batched fsync (graceful shutdown).
    pub fn sync_journal(&self) {
        let mut g = self.slots.lock().unwrap();
        if let Some(j) = g.journal.as_mut() {
            if let Err(e) = j.sync() {
                eprintln!("[store] journal sync failed: {e:#}");
            }
        }
    }

    /// Largest numeric suffix among ids shaped `<prefix><n>` — lets a
    /// restarted server resume its id counter past replayed results so
    /// fresh requests cannot collide with journaled ones.
    pub fn max_id_suffix(&self, prefix: &str) -> Option<u64> {
        let g = self.slots.lock().unwrap();
        g.map
            .keys()
            .filter_map(|id| id.strip_prefix(prefix))
            .filter_map(|rest| rest.parse::<u64>().ok())
            .max()
    }

    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nnscope-store-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn lifecycle_with_pickup_eviction() {
        let s = ObjectStore::new();
        assert!(s.peek("x").is_none());
        s.put_pending("x");
        assert_eq!(s.peek("x"), Some(Entry::Pending));
        s.put_ready("x", "{}".into());
        assert_eq!(s.peek("x"), Some(Entry::Ready("{}".into())));
        // pickup takes the entry with it
        assert_eq!(s.wait_ready("x", Duration::from_millis(1)), Some("{}".into()));
        assert!(s.peek("x").is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn wait_blocks_until_ready() {
        let s = Arc::new(ObjectStore::new());
        s.put_pending("r");
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            s2.put_ready("r", "done".into());
        });
        let t0 = Instant::now();
        let got = s.wait_ready("r", Duration::from_secs(5));
        assert_eq!(got, Some("done".into()));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        t.join().unwrap();
    }

    #[test]
    fn wait_times_out_on_pending() {
        let s = ObjectStore::new();
        s.put_pending("r");
        let got = s.wait_outcome("r", Duration::from_millis(20));
        assert!(got.is_none());
        // a timeout does not evict: the job may still complete
        assert_eq!(s.peek("r"), Some(Entry::Pending));
    }

    #[test]
    fn failure_propagates_and_evicts() {
        let s = ObjectStore::new();
        s.put_pending("r");
        s.put_failed("r", "boom");
        assert_eq!(
            s.wait_outcome("r", Duration::from_millis(1)),
            Some(Err("boom".into()))
        );
        assert!(s.peek("r").is_none());
    }

    #[test]
    fn ttl_expires_abandoned_results() {
        let s = ObjectStore::with_ttl(Duration::from_millis(20));
        s.put_ready("abandoned", "{}".into());
        s.put_failed("also-abandoned", "boom");
        s.put_pending("queued");
        std::thread::sleep(Duration::from_millis(40));
        // completed entries past the TTL are gone; pending survives to 4×
        assert_eq!(s.sweep_now(), 1);
        assert!(s.peek("abandoned").is_none());
        assert!(s.peek("also-abandoned").is_none());
        assert_eq!(s.peek("queued"), Some(Entry::Pending));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(s.sweep_now(), 0);
        assert!(s.peek("queued").is_none());
    }

    /// Regression test: the TTL sweep used to run only on writes, so a
    /// server that went idle after a burst (serving only result reads)
    /// never expired its map. Reads must sweep too.
    #[test]
    fn idle_server_expires_entries_on_reads_alone() {
        let s = ObjectStore::with_ttl(Duration::from_millis(20));
        s.put_ready("abandoned", "{}".into());
        assert_eq!(s.len(), 1);
        std::thread::sleep(Duration::from_millis(50));
        // no writes from here on: a read of a *different* id must still
        // trigger the sweep that expires the abandoned entry
        assert!(s.peek("something-else").is_none());
        assert_eq!(s.len(), 0, "read path must run the TTL sweep");

        // same through the wait path
        s.put_ready("abandoned-2", "{}".into());
        std::thread::sleep(Duration::from_millis(50));
        assert!(s.wait_outcome("unknown", Duration::from_millis(1)).is_none());
        assert_eq!(s.len(), 0, "wait path must run the TTL sweep");
    }

    #[test]
    fn sustained_traffic_stays_bounded() {
        // unfetched results must not accumulate past the TTL window
        let s = ObjectStore::with_ttl(Duration::from_millis(10));
        for i in 0..200 {
            s.put_ready(&format!("r{i}"), "{}".into());
            if i % 50 == 49 {
                std::thread::sleep(Duration::from_millis(15));
            }
        }
        std::thread::sleep(Duration::from_millis(15));
        assert!(s.sweep_now() < 200, "store grew without bound");
    }

    #[test]
    fn journaled_results_survive_restart_and_delivered_ones_do_not() {
        let dir = tmpdir("restart");
        let path = dir.join("results.journal");
        {
            let (s, rep) = ObjectStore::with_journal(Duration::from_secs(60), &path).unwrap();
            assert_eq!(rep.entries.len(), 0);
            s.put_pending("r-1");
            s.put_ready("r-1", "{\"saved\":1}".into());
            s.put_pending("r-2");
            s.put_failed("r-2", "exec error");
            s.put_pending("r-3");
            s.put_ready("r-3", "{\"saved\":3}".into());
            // r-3 is delivered pre-crash: must NOT come back after replay
            assert!(s.wait_ready("r-3", Duration::from_millis(1)).is_some());
            s.sync_journal();
            // store dropped without graceful shutdown = crash
        }
        let (s, rep) = ObjectStore::with_journal(Duration::from_secs(60), &path).unwrap();
        assert_eq!(rep.entries.len(), 2, "undelivered completed results replayed");
        assert_eq!(
            s.wait_ready("r-1", Duration::from_millis(1)),
            Some("{\"saved\":1}".into())
        );
        assert_eq!(
            s.wait_outcome("r-2", Duration::from_millis(1)),
            Some(Err("exec error".into()))
        );
        assert!(
            s.peek("r-3").is_none(),
            "evicted-before-crash result must not be resurrected"
        );
        assert_eq!(s.max_id_suffix("r-"), None, "all delivered by now");
    }

    #[test]
    fn pending_entries_are_not_durable() {
        let dir = tmpdir("pending");
        let path = dir.join("results.journal");
        {
            let (s, _) = ObjectStore::with_journal(Duration::from_secs(60), &path).unwrap();
            s.put_pending("r-9");
            s.sync_journal();
        }
        let (s, rep) = ObjectStore::with_journal(Duration::from_secs(60), &path).unwrap();
        assert_eq!(rep.entries.len(), 0, "pending work is the coordinator's to retry");
        assert!(s.is_empty());
    }

    #[test]
    fn max_id_suffix_resumes_counter() {
        let dir = tmpdir("suffix");
        let path = dir.join("results.journal");
        {
            let (s, _) = ObjectStore::with_journal(Duration::from_secs(60), &path).unwrap();
            s.put_ready("r-7", "{}".into());
            s.put_ready("r-12", "{}".into());
            s.put_ready("other-99", "{}".into());
            s.sync_journal();
        }
        let (s, _) = ObjectStore::with_journal(Duration::from_secs(60), &path).unwrap();
        assert_eq!(s.max_id_suffix("r-"), Some(12));
    }

    #[test]
    fn ttl_sweep_journals_evictions() {
        let dir = tmpdir("sweepjournal");
        let path = dir.join("results.journal");
        {
            let (s, _) = ObjectStore::with_journal(Duration::from_millis(10), &path).unwrap();
            s.put_ready("stale", "{}".into());
            std::thread::sleep(Duration::from_millis(30));
            assert_eq!(s.sweep_now(), 0);
            s.sync_journal();
        }
        let (_s, rep) = ObjectStore::with_journal(Duration::from_millis(10), &path).unwrap();
        assert_eq!(rep.entries.len(), 0, "TTL-evicted entries must not replay");
    }

    #[test]
    fn lost_write_failpoint_drops_result() {
        use crate::util::failpoint::{Armed, FailAction, Spec};
        let s = ObjectStore::new();
        let _g = Armed::new("store.put", Spec::nth(0, FailAction::Skip));
        s.put_ready("ghost", "{}".into());
        assert!(s.peek("ghost").is_none(), "injected lost write");
        s.put_ready("real", "{}".into());
        assert!(s.peek("real").is_some());
    }
}
