//! The object store (§B.2, Fig. 4): completed intervention results parked
//! for client pickup.
//!
//! In the paper, shard 0 pushes results to the frontend's object store and
//! a websocket notifies the client, which then pulls. Offline we replace
//! the websocket with condvar-backed long-polling: `GET /v1/result/<id>`
//! blocks (bounded) until the entry is ready — same lifecycle, one fewer
//! protocol.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Entry lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub enum Entry {
    Pending,
    Ready(String),
    Failed(String),
}

/// Thread-safe result store with wakeups.
pub struct ObjectStore {
    entries: Mutex<HashMap<String, Entry>>,
    cv: Condvar,
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectStore {
    pub fn new() -> ObjectStore {
        ObjectStore { entries: Mutex::new(HashMap::new()), cv: Condvar::new() }
    }

    /// Register a pending request id.
    pub fn put_pending(&self, id: &str) {
        self.entries
            .lock()
            .unwrap()
            .insert(id.to_string(), Entry::Pending);
    }

    pub fn put_ready(&self, id: &str, json: String) {
        self.entries
            .lock()
            .unwrap()
            .insert(id.to_string(), Entry::Ready(json));
        self.cv.notify_all();
    }

    pub fn put_failed(&self, id: &str, err: &str) {
        self.entries
            .lock()
            .unwrap()
            .insert(id.to_string(), Entry::Failed(err.to_string()));
        self.cv.notify_all();
    }

    /// Current state without blocking (None = unknown id).
    pub fn peek(&self, id: &str) -> Option<Entry> {
        self.entries.lock().unwrap().get(id).cloned()
    }

    /// Block until the entry leaves Pending or the timeout passes.
    /// Returns None on unknown id or timeout-while-pending.
    pub fn wait_outcome(&self, id: &str, timeout: Duration) -> Option<Result<String, String>> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.entries.lock().unwrap();
        loop {
            match guard.get(id) {
                None => return None,
                Some(Entry::Ready(s)) => return Some(Ok(s.clone())),
                Some(Entry::Failed(e)) => return Some(Err(e.clone())),
                Some(Entry::Pending) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                    guard = g;
                }
            }
        }
    }

    /// Like [`ObjectStore::wait_outcome`] but only for success payloads.
    pub fn wait_ready(&self, id: &str, timeout: Duration) -> Option<String> {
        match self.wait_outcome(id, timeout) {
            Some(Ok(s)) => Some(s),
            _ => None,
        }
    }

    /// Remove a delivered entry (client fetched it).
    pub fn remove(&self, id: &str) -> Option<Entry> {
        self.entries.lock().unwrap().remove(id)
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lifecycle() {
        let s = ObjectStore::new();
        assert!(s.peek("x").is_none());
        s.put_pending("x");
        assert_eq!(s.peek("x"), Some(Entry::Pending));
        s.put_ready("x", "{}".into());
        assert_eq!(s.peek("x"), Some(Entry::Ready("{}".into())));
        assert_eq!(s.wait_ready("x", Duration::from_millis(1)), Some("{}".into()));
        s.remove("x");
        assert!(s.peek("x").is_none());
    }

    #[test]
    fn wait_blocks_until_ready() {
        let s = Arc::new(ObjectStore::new());
        s.put_pending("r");
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            s2.put_ready("r", "done".into());
        });
        let t0 = Instant::now();
        let got = s.wait_ready("r", Duration::from_secs(5));
        assert_eq!(got, Some("done".into()));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        t.join().unwrap();
    }

    #[test]
    fn wait_times_out_on_pending() {
        let s = ObjectStore::new();
        s.put_pending("r");
        let got = s.wait_outcome("r", Duration::from_millis(20));
        assert!(got.is_none());
    }

    #[test]
    fn failure_propagates() {
        let s = ObjectStore::new();
        s.put_pending("r");
        s.put_failed("r", "boom");
        assert_eq!(
            s.wait_outcome("r", Duration::from_millis(1)),
            Some(Err("boom".into()))
        );
    }
}
