//! The object store (§B.2, Fig. 4): completed intervention results parked
//! for client pickup.
//!
//! In the paper, shard 0 pushes results to the frontend's object store and
//! a websocket notifies the client, which then pulls. Offline we replace
//! the websocket with condvar-backed long-polling: `GET /v1/result/<id>`
//! blocks (bounded) until the entry is ready — same lifecycle, one fewer
//! protocol.
//!
//! Memory is bounded two ways so the map cannot grow forever under
//! sustained traffic:
//! * **eviction on pickup** — [`ObjectStore::wait_outcome`] *takes* a
//!   `Ready`/`Failed` entry out of the map as it hands it to the waiter
//!   (first puller wins; a re-poll of a delivered id is a 404, which was
//!   already the contract when callers removed after reading);
//! * **TTL expiry** — entries a client abandoned are swept on subsequent
//!   store writes: `Ready`/`Failed` entries older than the TTL, and
//!   `Pending` entries older than 4× the TTL (pending work may
//!   legitimately sit behind a deep queue; results nobody ever asked for
//!   must still go away).

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Entry lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub enum Entry {
    Pending,
    Ready(String),
    Failed(String),
}

struct Slot {
    entry: Entry,
    at: Instant,
}

struct Slots {
    map: HashMap<String, Slot>,
    last_sweep: Instant,
}

/// Thread-safe result store with wakeups, bounded by pickup-eviction and
/// TTL expiry.
pub struct ObjectStore {
    slots: Mutex<Slots>,
    cv: Condvar,
    ttl: Duration,
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectStore {
    /// Default TTL: long enough for the longest legitimate long-poll
    /// cadence, short enough that abandoned results don't accumulate.
    pub const DEFAULT_TTL: Duration = Duration::from_secs(600);

    pub fn new() -> ObjectStore {
        ObjectStore::with_ttl(Self::DEFAULT_TTL)
    }

    pub fn with_ttl(ttl: Duration) -> ObjectStore {
        ObjectStore {
            slots: Mutex::new(Slots { map: HashMap::new(), last_sweep: Instant::now() }),
            cv: Condvar::new(),
            ttl,
        }
    }

    fn put(&self, id: &str, entry: Entry) {
        let mut g = self.slots.lock().unwrap();
        Self::maybe_sweep(&mut g, self.ttl, false);
        g.map
            .insert(id.to_string(), Slot { entry, at: Instant::now() });
    }

    /// Sweep at most every `ttl / 4` so writes stay O(1) amortized.
    fn maybe_sweep(g: &mut Slots, ttl: Duration, force: bool) {
        if !force && g.last_sweep.elapsed() < ttl / 4 {
            return;
        }
        g.last_sweep = Instant::now();
        g.map.retain(|_, s| {
            let limit = match s.entry {
                Entry::Pending => ttl * 4,
                _ => ttl,
            };
            s.at.elapsed() <= limit
        });
    }

    /// Register a pending request id.
    pub fn put_pending(&self, id: &str) {
        self.put(id, Entry::Pending);
    }

    pub fn put_ready(&self, id: &str, json: String) {
        self.put(id, Entry::Ready(json));
        self.cv.notify_all();
    }

    pub fn put_failed(&self, id: &str, err: &str) {
        self.put(id, Entry::Failed(err.to_string()));
        self.cv.notify_all();
    }

    /// Current state without blocking (None = unknown id). Does not evict.
    pub fn peek(&self, id: &str) -> Option<Entry> {
        self.slots.lock().unwrap().map.get(id).map(|s| s.entry.clone())
    }

    /// Block until the entry leaves Pending or the timeout passes,
    /// **taking** the completed entry out of the store (eviction on
    /// pickup). Returns None on unknown id or timeout-while-pending.
    pub fn wait_outcome(&self, id: &str, timeout: Duration) -> Option<Result<String, String>> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.slots.lock().unwrap();
        loop {
            match guard.map.get(id).map(|s| &s.entry) {
                None => return None,
                Some(Entry::Ready(_) | Entry::Failed(_)) => {
                    let slot = guard.map.remove(id).expect("presence checked above");
                    return Some(match slot.entry {
                        Entry::Ready(s) => Ok(s),
                        Entry::Failed(e) => Err(e),
                        Entry::Pending => unreachable!("matched completed above"),
                    });
                }
                Some(Entry::Pending) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                    guard = g;
                }
            }
        }
    }

    /// Like [`ObjectStore::wait_outcome`] but only for success payloads.
    pub fn wait_ready(&self, id: &str, timeout: Duration) -> Option<String> {
        match self.wait_outcome(id, timeout) {
            Some(Ok(s)) => Some(s),
            _ => None,
        }
    }

    /// Remove an entry regardless of state (cancellation paths).
    pub fn remove(&self, id: &str) -> Option<Entry> {
        self.slots.lock().unwrap().map.remove(id).map(|s| s.entry)
    }

    /// Force-expire overdue entries now (tests); returns how many remain.
    pub fn sweep_now(&self) -> usize {
        let mut g = self.slots.lock().unwrap();
        Self::maybe_sweep(&mut g, self.ttl, true);
        g.map.len()
    }

    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lifecycle_with_pickup_eviction() {
        let s = ObjectStore::new();
        assert!(s.peek("x").is_none());
        s.put_pending("x");
        assert_eq!(s.peek("x"), Some(Entry::Pending));
        s.put_ready("x", "{}".into());
        assert_eq!(s.peek("x"), Some(Entry::Ready("{}".into())));
        // pickup takes the entry with it
        assert_eq!(s.wait_ready("x", Duration::from_millis(1)), Some("{}".into()));
        assert!(s.peek("x").is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn wait_blocks_until_ready() {
        let s = Arc::new(ObjectStore::new());
        s.put_pending("r");
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            s2.put_ready("r", "done".into());
        });
        let t0 = Instant::now();
        let got = s.wait_ready("r", Duration::from_secs(5));
        assert_eq!(got, Some("done".into()));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        t.join().unwrap();
    }

    #[test]
    fn wait_times_out_on_pending() {
        let s = ObjectStore::new();
        s.put_pending("r");
        let got = s.wait_outcome("r", Duration::from_millis(20));
        assert!(got.is_none());
        // a timeout does not evict: the job may still complete
        assert_eq!(s.peek("r"), Some(Entry::Pending));
    }

    #[test]
    fn failure_propagates_and_evicts() {
        let s = ObjectStore::new();
        s.put_pending("r");
        s.put_failed("r", "boom");
        assert_eq!(
            s.wait_outcome("r", Duration::from_millis(1)),
            Some(Err("boom".into()))
        );
        assert!(s.peek("r").is_none());
    }

    #[test]
    fn ttl_expires_abandoned_results() {
        let s = ObjectStore::with_ttl(Duration::from_millis(20));
        s.put_ready("abandoned", "{}".into());
        s.put_failed("also-abandoned", "boom");
        s.put_pending("queued");
        std::thread::sleep(Duration::from_millis(40));
        // completed entries past the TTL are gone; pending survives to 4×
        assert_eq!(s.sweep_now(), 1);
        assert!(s.peek("abandoned").is_none());
        assert!(s.peek("also-abandoned").is_none());
        assert_eq!(s.peek("queued"), Some(Entry::Pending));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(s.sweep_now(), 0);
        assert!(s.peek("queued").is_none());
    }

    #[test]
    fn sustained_traffic_stays_bounded() {
        // unfetched results must not accumulate past the TTL window
        let s = ObjectStore::with_ttl(Duration::from_millis(10));
        for i in 0..200 {
            s.put_ready(&format!("r{i}"), "{}".into());
            if i % 50 == 49 {
                std::thread::sleep(Duration::from_millis(15));
            }
        }
        std::thread::sleep(Duration::from_millis(15));
        assert!(s.sweep_now() < 200, "store grew without bound");
    }
}
