//! Autoregressive generation over the fixed-window artifacts.
//!
//! The exported modules are shape-specialized to `[batch, seq]`; decoding
//! slides the window: each step runs a full forward, takes the argmax of
//! the last position, shifts the context left by one and appends the new
//! token. This is O(steps × forward) — the compatibility path for the
//! AOT artifacts. The native decode engine (`crate::engine`) replaces it
//! with a per-sequence KV cache and O(1)-per-step decode; both paths share
//! [`argmax_row`] so greedy tie-breaking is identical.
//!
//! Generation composes with interventions: pass any [`Hooks`] and it is
//! applied at every decode step — steering generation, the paper's
//! Fig. 3 use case extended over time.

use anyhow::Result;

use crate::tensor::Tensor;

use super::runner::{Hooks, ModelRunner, NoHooks};

/// Result of a generation run.
#[derive(Debug, Clone)]
pub struct Generation {
    /// Newly generated token ids, in order.
    pub tokens: Vec<usize>,
    /// Logit of each chosen token at its step (greedy score).
    pub scores: Vec<f32>,
}

/// Greedy pick over one logits row: first-max argmax plus its logit. The
/// single tie-breaking rule for every decode path — sliding-window and
/// KV-cached engines must agree bit-for-bit on the chosen token.
pub fn argmax_row(row: &[f32]) -> (usize, f32) {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    (best, row[best])
}

/// One greedy decode step over the sliding window: pick the argmax of the
/// last position of `[1, seq, vocab]` logits, shift the `[1, seq]` context
/// left by one, and append the chosen token. Returns `(token, logit)`.
/// Shared by [`ModelRunner::generate`] and the streaming interpreter
/// (`crate::interp::execute_stream`).
pub fn advance_window(ctx: &mut Tensor, logits: &Tensor, seq: usize, vocab: usize) -> (usize, f32) {
    // argmax straight off the last-position row — no slice/reshape
    // materialization per step
    let (best, score) = argmax_row(&logits.data()[(seq - 1) * vocab..seq * vocab]);
    let cd = ctx.data_mut();
    cd.copy_within(1..seq, 0);
    cd[seq - 1] = best as f32;
    (best, score)
}

impl ModelRunner {
    /// Greedy-decode `steps` tokens from a `[1, seq]` prompt, applying
    /// `hooks` at every step's forward pass.
    pub fn generate(
        &self,
        prompt: &Tensor,
        steps: usize,
        hooks: &mut dyn Hooks,
    ) -> Result<Generation> {
        assert_eq!(prompt.rank(), 2);
        assert_eq!(prompt.dims()[0], 1, "generation is single-sequence");
        let seq = self.manifest.seq;
        assert_eq!(prompt.dims()[1], seq);
        let vocab = self.manifest.vocab;

        let mut ctx = prompt.clone();
        let mut out = Generation { tokens: Vec::with_capacity(steps), scores: Vec::new() };
        for _ in 0..steps {
            let logits = self.forward(&ctx, hooks)?;
            let (token, score) = advance_window(&mut ctx, &logits, seq, vocab);
            out.tokens.push(token);
            out.scores.push(score);
        }
        Ok(out)
    }

    /// Greedy decode without interventions.
    pub fn generate_plain(&self, prompt: &Tensor, steps: usize) -> Result<Generation> {
        self.generate(prompt, steps, &mut NoHooks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::artifacts_dir;
    use crate::tensor::Range1;

    fn runner() -> ModelRunner {
        ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap()
    }

    #[test]
    fn generates_requested_steps_within_vocab() {
        let r = runner();
        let prompt = Tensor::new(&[1, 16], (0..16).map(|i| (i % 9) as f32).collect());
        let g = r.generate_plain(&prompt, 5).unwrap();
        assert_eq!(g.tokens.len(), 5);
        assert!(g.tokens.iter().all(|&t| t < r.manifest.vocab));
        assert!(g.scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn generation_is_deterministic() {
        let r = runner();
        let prompt = Tensor::new(&[1, 16], (0..16).map(|i| (i % 5) as f32).collect());
        let a = r.generate_plain(&prompt, 4).unwrap();
        let b = r.generate_plain(&prompt, 4).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn streaming_decode_matches_plain_generation() {
        use crate::client::Trace;
        let r = runner();
        let prompt = Tensor::new(&[1, 16], (0..16).map(|i| (i % 9) as f32).collect());
        let plain = r.generate_plain(&prompt, 4).unwrap();

        // a pure probe (step-hook the mean of layer.0) must not perturb
        // the greedy trajectory, and must fire once per step
        let mut tr = Trace::new("tiny-sim", &prompt);
        let h = tr.output("layer.0");
        let m = tr.mean(h);
        let hook = tr.step_hook(m);
        let graph = tr.into_graph();
        let mut events = Vec::new();
        let gen = crate::interp::execute_stream(&graph, &r, 4, &mut |step, out| {
            assert!(out.values.get(hook.0).is_some(), "step {step} missing hooked value");
            events.push(out.token);
            true
        })
        .unwrap();
        assert_eq!(gen.tokens, plain.tokens);
        assert_eq!(events, plain.tokens);
    }

    #[test]
    fn streaming_sink_can_stop_early() {
        use crate::client::Trace;
        let r = runner();
        let prompt = Tensor::new(&[1, 16], (0..16).map(|i| (i % 5) as f32).collect());
        let mut tr = Trace::new("tiny-sim", &prompt);
        let h = tr.output("layer.0");
        let m = tr.mean(h);
        tr.step_hook(m);
        let graph = tr.into_graph();
        let gen = crate::interp::execute_stream(&graph, &r, 10, &mut |_, _| false).unwrap();
        assert_eq!(gen.tokens.len(), 1, "sink=false must stop decoding");
    }

    #[test]
    fn steering_hook_changes_generation() {
        struct Steer;
        impl Hooks for Steer {
            fn wants(&self, p: &str) -> bool {
                p == "layer.0"
            }
            fn on_output(&mut self, _p: &str, t: &mut Tensor) -> bool {
                let dims = t.dims().to_vec();
                t.slice_fill(
                    &[Range1::all(), Range1::one(dims[1] - 1), Range1::new(0, 8)],
                    4.0,
                );
                true
            }
        }
        let r = runner();
        let prompt = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
        let plain = r.generate_plain(&prompt, 4).unwrap();
        let steered = r.generate(&prompt, 4, &mut Steer).unwrap();
        assert_ne!(plain.tokens, steered.tokens, "steering had no effect");
    }
}
