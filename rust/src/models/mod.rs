//! Model substrate: synthetic weights, the module-sequence runner, and
//! workload generation (IOI-style prompts, load-test requests).
//!
//! Models are defined entirely by their artifact manifests
//! (`artifacts/<name>/manifest.json`); the Rust side has no hardcoded
//! architecture knowledge beyond the module-kind naming scheme.

pub mod generate;
pub mod runner;
pub mod weights;
pub mod workload;

pub use runner::{Hooks, ModelRunner, NoHooks};
pub use weights::ModelWeights;

use std::path::{Path, PathBuf};

/// Default artifacts directory: `$NNSCOPE_ARTIFACTS` or `<crate>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("NNSCOPE_ARTIFACTS") {
        return PathBuf::from(d);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
