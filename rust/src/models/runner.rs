//! The model runner: executes the AOT module sequence with hook points.
//!
//! This is the Rust realization of the paper's interleaving mechanism
//! (§B.1): NNsight registers PyTorch hooks at module boundaries and runs
//! intervention sub-graphs when those hooks fire; here, module boundaries
//! are artifact boundaries, and a [`Hooks`] implementation is invoked
//! between module executions. Hidden states stay device-resident between
//! modules; they cross to the host only at boundaries a hook actually
//! wants (§Perf).
//!
//! The runner also provides:
//! * [`ModelRunner::forward_sharded`] — the simulated tensor-parallel
//!   deployment (Fig. 4): S shard workers execute per-shard partial layer
//!   artifacts in parallel, and the runner performs the all-reduce;
//! * [`ModelRunner::backward`] — the GradProtocol substrate: loss +
//!   hidden-state gradients via the exported `lm_head_grad` and
//!   `layer_vjp` artifacts, chained in reverse.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::runtime::{DeviceTensor, Engine, Executable, Manifest};
use crate::tensor::Tensor;
use crate::threadpool;

use super::weights::ModelWeights;

/// Hook interface invoked at module boundaries during a forward pass.
///
/// `wants(point)` gates the host transfer: if no hook wants a point, the
/// hidden state never leaves the device. `on_output` may mutate the tensor
/// (a *setter* in intervention-graph terms) and must return `true` iff it
/// did, so the runner knows to re-upload.
pub trait Hooks {
    fn wants(&self, point: &str) -> bool;
    fn on_output(&mut self, point: &str, t: &mut Tensor) -> bool;
}

/// No interventions: the plain inference path.
pub struct NoHooks;

impl Hooks for NoHooks {
    fn wants(&self, _point: &str) -> bool {
        false
    }
    fn on_output(&mut self, _point: &str, _t: &mut Tensor) -> bool {
        false
    }
}

/// A loaded model: compiled executables + device-resident weights.
pub struct ModelRunner {
    pub manifest: Manifest,
    engine: Arc<Engine>,
    /// (module kind, batch) -> compiled executable.
    exes: Mutex<HashMap<(String, usize), Arc<Executable>>>,
    /// module key -> device weight buffers (upload-once cache).
    wbufs: Mutex<HashMap<String, Arc<Vec<DeviceTensor>>>>,
    /// host weights (kept for sharding / persistence).
    pub weights: Arc<ModelWeights>,
}

impl ModelRunner {
    /// Load with generated weights (the NDIF preload path). Compiles the
    /// forward modules for every exported batch size eagerly.
    pub fn load(artifacts_dir: &std::path::Path, name: &str) -> Result<ModelRunner> {
        let manifest = Manifest::load(artifacts_dir, name)?;
        let weights = ModelWeights::generate(&manifest);
        let r = ModelRunner::new(manifest, weights)?;
        r.precompile_forward()?;
        Ok(r)
    }

    /// Load with weights read from `weights.bin` and **no** precompilation
    /// — the cold HPC path whose setup time the benchmarks measure.
    pub fn load_cold(artifacts_dir: &std::path::Path, name: &str) -> Result<ModelRunner> {
        let manifest = Manifest::load(artifacts_dir, name)?;
        let path = manifest.dir.join("weights.bin");
        let weights = if path.exists() {
            ModelWeights::load(&path, name)?
        } else {
            ModelWeights::generate(&manifest)
        };
        ModelRunner::new(manifest, weights)
    }

    pub fn new(manifest: Manifest, weights: ModelWeights) -> Result<ModelRunner> {
        Ok(ModelRunner {
            manifest,
            engine: Engine::global(),
            exes: Mutex::new(HashMap::new()),
            wbufs: Mutex::new(HashMap::new()),
            weights: Arc::new(weights),
        })
    }

    /// Compile forward modules (embed/layer/lm_head) at all exported batch
    /// sizes and upload all weights — everything a request will need.
    pub fn precompile_forward(&self) -> Result<()> {
        for b in self.manifest.batches.clone() {
            for kind in ["embed", "layer", "lm_head"] {
                self.executable(kind, b)?;
            }
        }
        for key in self.weights.modules.keys() {
            self.weight_buffers(key)?;
        }
        Ok(())
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Batch sizes the model was exported at (ascending).
    pub fn available_batches(&self) -> &[usize] {
        &self.manifest.batches
    }

    /// Smallest exported batch size that fits `n` rows.
    pub fn batch_for(&self, n: usize) -> Result<usize> {
        self.manifest
            .batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| {
                anyhow!(
                    "no exported batch size fits {n} rows (available {:?})",
                    self.manifest.batches
                )
            })
    }

    /// Get (compiling on first use) the executable for a module kind.
    pub fn executable(&self, kind: &str, batch: usize) -> Result<Arc<Executable>> {
        let key = (kind.to_string(), batch);
        if let Some(e) = self.exes.lock().unwrap().get(&key) {
            return Ok(Arc::clone(e));
        }
        // compile outside the lock (compiles can be slow)
        let path = self.manifest.module_path(kind, batch)?;
        let exe = Arc::new(self.engine.compile_file(&path)?);
        let mut g = self.exes.lock().unwrap();
        Ok(Arc::clone(g.entry(key).or_insert(exe)))
    }

    /// Device buffers for a module's weights (upload-once).
    pub fn weight_buffers(&self, module_key: &str) -> Result<Arc<Vec<DeviceTensor>>> {
        if let Some(b) = self.wbufs.lock().unwrap().get(module_key) {
            return Ok(Arc::clone(b));
        }
        let tensors = self
            .weights
            .modules
            .get(module_key)
            .ok_or_else(|| anyhow!("no weights for module {module_key}"))?;
        let bufs: Vec<DeviceTensor> =
            tensors.iter().map(|t| self.engine.upload(t)).collect::<Result<_>>()?;
        let arc = Arc::new(bufs);
        let mut g = self.wbufs.lock().unwrap();
        Ok(Arc::clone(g.entry(module_key.to_string()).or_insert(arc)))
    }

    /// Pad a `[n, seq]` token tensor up to an exported batch size.
    pub fn pad_tokens(&self, tokens: &Tensor) -> Result<(Tensor, usize)> {
        assert_eq!(tokens.rank(), 2, "tokens must be [batch, seq]");
        let n = tokens.dims()[0];
        assert_eq!(tokens.dims()[1], self.manifest.seq, "seq mismatch");
        let b = self.batch_for(n)?;
        if b == n {
            return Ok((tokens.clone(), n));
        }
        let pad = Tensor::zeros(&[b - n, self.manifest.seq]);
        Ok((Tensor::concat(&[tokens, &pad], 0), n))
    }

    // -----------------------------------------------------------------------
    // Forward
    // -----------------------------------------------------------------------

    /// Run the forward module sequence with hooks; returns `[b, seq, vocab]`
    /// logits. `tokens` must be `[b, seq]` with `b` an exported batch size
    /// (use [`ModelRunner::pad_tokens`] otherwise).
    pub fn forward(&self, tokens: &Tensor, hooks: &mut dyn Hooks) -> Result<Tensor> {
        let b = tokens.dims()[0];
        let mut dev = self.engine.upload(tokens)?;
        for point in self.manifest.forward_sequence() {
            let kind = Manifest::module_kind(&point);
            let exe = self.executable(kind, b)?;
            let wbufs = self.weight_buffers(&point)?;
            let mut args: Vec<&DeviceTensor> = Vec::with_capacity(1 + wbufs.len());
            args.push(&dev);
            args.extend(wbufs.iter());
            dev = exe.run(&args, &self.manifest.output_dims(kind, b))?;
            if hooks.wants(&point) {
                let mut t = dev.download()?;
                if hooks.on_output(&point, &mut t) {
                    dev = self.engine.upload(&t)?;
                }
            }
        }
        dev.download()
    }

    /// Plain forward with no interventions.
    pub fn forward_plain(&self, tokens: &Tensor) -> Result<Tensor> {
        self.forward(tokens, &mut NoHooks)
    }

    // -----------------------------------------------------------------------
    // Sharded forward (tensor-parallel simulation, Fig. 4)
    // -----------------------------------------------------------------------

    /// Forward with each layer executed as S tensor-parallel shards.
    ///
    /// Per layer: shard workers compute partial attention deltas in
    /// parallel → all-reduce (sum) + residual → partial MLP deltas →
    /// all-reduce + residual. Numerics must match [`ModelRunner::forward`]
    /// (verified in integration tests). Hidden states move through the
    /// host at shard boundaries, mirroring the DTensor gather/re-shard
    /// described in §B.2.
    pub fn forward_sharded(
        &self,
        tokens: &Tensor,
        shards: usize,
        hooks: &mut dyn Hooks,
    ) -> Result<Tensor> {
        if !self.manifest.tp.contains(&shards) {
            return Err(anyhow!(
                "model {} not exported for tp={shards} (available {:?})",
                self.manifest.name,
                self.manifest.tp
            ));
        }
        let b = tokens.dims()[0];
        let attn_kind = format!("attn_tp{shards}");
        let mlp_kind = format!("mlp_tp{shards}");
        let attn_exe = self.executable(&attn_kind, b)?;
        let mlp_exe = self.executable(&mlp_kind, b)?;

        // embed on the head shard
        let embed_exe = self.executable("embed", b)?;
        let wbufs = self.weight_buffers("embed")?;
        let tok_dev = self.engine.upload(tokens)?;
        let mut args: Vec<&DeviceTensor> = vec![&tok_dev];
        args.extend(wbufs.iter());
        let dev = embed_exe.run(&args, &self.manifest.output_dims("embed", b))?;
        let mut x = dev.download()?;
        if hooks.wants("embed") {
            hooks.on_output("embed", &mut x);
        }

        let out_dims = self.manifest.output_dims("layer", b);
        for i in 0..self.manifest.n_layers {
            let key = format!("layer.{i}");
            // the per-shard weight sets are moved into the worker closures
            // (not cloned): each shard owns its partials for the layer
            let (attn_parts, mlp_parts): (Vec<_>, Vec<_>) =
                self.weights.shard_layer(&key, shards).into_iter().unzip();

            // phase 1: attention partials in parallel, then all-reduce.
            // The hidden state is shared with the workers by Arc and
            // reclaimed afterwards — zero copies of `x` per phase.
            let x_arc = Arc::new(x);
            let jobs: Vec<_> = attn_parts
                .into_iter()
                .map(|w| {
                    let exe = Arc::clone(&attn_exe);
                    let eng = Arc::clone(&self.engine);
                    let xs = Arc::clone(&x_arc);
                    let od = out_dims.clone();
                    move || -> Result<Tensor> {
                        let xd = eng.upload(&xs)?;
                        let wd: Vec<DeviceTensor> =
                            w.iter().map(|t| eng.upload(t)).collect::<Result<_>>()?;
                        let mut args: Vec<&DeviceTensor> = vec![&xd];
                        args.extend(wd.iter());
                        exe.run(&args, &od)?.download()
                    }
                })
                .collect();
            let partials = threadpool::parallel_map(jobs, shards);
            // workers have finished and dropped their refs; the fallback
            // clone is unreachable in practice
            let mut h = Arc::try_unwrap(x_arc).unwrap_or_else(|a| (*a).clone());
            for p in partials {
                h.add_assign(&p?);
            }

            // phase 2: MLP partials, all-reduce
            let h_arc = Arc::new(h);
            let jobs: Vec<_> = mlp_parts
                .into_iter()
                .map(|w| {
                    let exe = Arc::clone(&mlp_exe);
                    let eng = Arc::clone(&self.engine);
                    let hs = Arc::clone(&h_arc);
                    let od = out_dims.clone();
                    move || -> Result<Tensor> {
                        let hd = eng.upload(&hs)?;
                        let wd: Vec<DeviceTensor> =
                            w.iter().map(|t| eng.upload(t)).collect::<Result<_>>()?;
                        let mut args: Vec<&DeviceTensor> = vec![&hd];
                        args.extend(wd.iter());
                        exe.run(&args, &od)?.download()
                    }
                })
                .collect();
            let partials = threadpool::parallel_map(jobs, shards);
            let mut out = Arc::try_unwrap(h_arc).unwrap_or_else(|a| (*a).clone());
            for p in partials {
                out.add_assign(&p?);
            }
            x = out;
            if hooks.wants(&key) {
                hooks.on_output(&key, &mut x);
            }
        }

        // lm_head on the head shard
        let head_exe = self.executable("lm_head", b)?;
        let wbufs = self.weight_buffers("lm_head")?;
        let xd = self.engine.upload(&x)?;
        let mut args: Vec<&DeviceTensor> = vec![&xd];
        args.extend(wbufs.iter());
        let mut logits = head_exe
            .run(&args, &self.manifest.output_dims("lm_head", b))?
            .download()?;
        if hooks.wants("lm_head") {
            hooks.on_output("lm_head", &mut logits);
        }
        Ok(logits)
    }

    // -----------------------------------------------------------------------
    // Backward (GradProtocol substrate)
    // -----------------------------------------------------------------------

    /// Loss + gradients of the loss w.r.t. the outputs of the requested
    /// layer points. Requires the model to have been exported with grad
    /// modules. Returns `(loss, {point -> grad [b,seq,d]})`.
    ///
    /// Implementation: forward capturing each layer's input; `lm_head_grad`
    /// yields ∂loss/∂h_N; `layer_vjp` chains it backwards one layer at a
    /// time. ∂loss/∂(output of layer i) is the cotangent *entering* layer
    /// i+1's vjp, i.e. the running cotangent after processing layers
    /// N-1..i+1.
    pub fn backward(
        &self,
        tokens: &Tensor,
        targets: &Tensor,
        points: &[String],
    ) -> Result<(f32, HashMap<String, Tensor>)> {
        if !self.manifest.grad {
            return Err(anyhow!("model {} exported without grad modules", self.manifest.name));
        }
        let b = tokens.dims()[0];
        let n = self.manifest.n_layers;

        // forward, capturing each layer's input (= previous module output)
        let mut inputs: Vec<Tensor> = Vec::with_capacity(n);
        struct Capture<'a> {
            inputs: &'a mut Vec<Tensor>,
            n: usize,
        }
        impl Hooks for Capture<'_> {
            fn wants(&self, point: &str) -> bool {
                // need outputs of embed .. layer.{n-2} = inputs of layers
                point == "embed"
                    || point
                        .strip_prefix("layer.")
                        .and_then(|s| s.parse::<usize>().ok())
                        .map(|i| i + 1 < self.n)
                        .unwrap_or(false)
            }
            fn on_output(&mut self, _point: &str, t: &mut Tensor) -> bool {
                self.inputs.push(t.clone());
                false
            }
        }
        let mut cap = Capture { inputs: &mut inputs, n };
        let _ = self.forward(tokens, &mut cap)?;
        debug_assert_eq!(inputs.len(), n);

        // final hidden = forward of last layer over its input
        let final_hidden = {
            let exe = self.executable("layer", b)?;
            let wb = self.weight_buffers(&format!("layer.{}", n - 1))?;
            let xd = self.engine.upload(&inputs[n - 1])?;
            let mut args: Vec<&DeviceTensor> = vec![&xd];
            args.extend(wb.iter());
            exe.run(&args, &self.manifest.output_dims("layer", b))?.download()?
        };

        // loss + dloss/dh_N
        let grad_exe = self.executable("lm_head_grad", b)?;
        let head_w = self.weight_buffers("lm_head")?;
        let xd = self.engine.upload(&final_hidden)?;
        let td = self.engine.upload(targets)?;
        let mut args: Vec<&DeviceTensor> = vec![&xd];
        args.extend(head_w.iter());
        args.push(&td);
        let outs = grad_exe.run_tupled(
            &args,
            &[vec![], vec![b, self.manifest.seq, self.manifest.d_model]],
        )?;
        let loss = outs[0].item();
        let mut g = outs[1].clone();

        // chain vjp backwards; record grads at requested points
        let mut grads = HashMap::new();
        let record = |grads: &mut HashMap<String, Tensor>, point: String, g: &Tensor| {
            if points.contains(&point) {
                grads.insert(point, g.clone());
            }
        };
        record(&mut grads, format!("layer.{}", n - 1), &g);
        let vjp_exe = self.executable("layer_vjp", b)?;
        for i in (0..n).rev() {
            let wb = self.weight_buffers(&format!("layer.{i}"))?;
            let xd = self.engine.upload(&inputs[i])?;
            let gd = self.engine.upload(&g)?;
            let mut args: Vec<&DeviceTensor> = vec![&xd];
            args.extend(wb.iter());
            args.push(&gd);
            g = vjp_exe
                .run(&args, &self.manifest.output_dims("layer", b))?
                .download()?;
            if i > 0 {
                record(&mut grads, format!("layer.{}", i - 1), &g);
            } else {
                record(&mut grads, "embed".to_string(), &g);
            }
        }
        Ok((loss, grads))
    }
}
