//! Synthetic model weights — Rust side of the shared generation contract
//! (mirrored bit-for-bit by `python/compile/weights.py`; see the init
//! rules there).
//!
//! Weights can be persisted to / loaded from `weights.bin` so the HPC
//! baseline's *setup time* measures a real disk-load + device-upload path,
//! as in the paper's Fig. 6a / Table 2 (where HPC setup is dominated by
//! weight loading and grows with parameter count).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::Manifest;
use crate::tensor::{Range1, Tensor};
use crate::util::Prng;

/// Standard deviation of the synthetic weight distribution (shared
/// contract with python; manifest records it too).
pub const WEIGHT_STD: f64 = 0.02;

fn uniform_halfwidth() -> f64 {
    WEIGHT_STD * 3.0_f64.sqrt()
}

/// Is this parameter a layernorm gain (init to ones)?
pub fn is_gain(param: &str) -> bool {
    param.ends_with("_g")
}

/// Is this parameter a bias (init to zeros)?
pub fn is_bias(param: &str) -> bool {
    param.ends_with("_b") || matches!(param, "bo" | "b1" | "b2")
}

/// Generate one parameter tensor by the shared contract.
pub fn gen_param(cfg_name: &str, module: &str, param: &str, dims: &[usize]) -> Tensor {
    if is_gain(param) {
        return Tensor::full(dims, 1.0);
    }
    if is_bias(param) {
        return Tensor::zeros(dims);
    }
    let mut t = Tensor::zeros(dims);
    let mut rng = Prng::from_name(&format!("{cfg_name}/{module}/{param}"));
    rng.fill_uniform_sym(t.data_mut(), uniform_halfwidth());
    t
}

/// All weights for a model, keyed by module path (`embed`, `layer.<i>`,
/// `lm_head`).
#[derive(Clone)]
pub struct ModelWeights {
    pub model: String,
    pub modules: BTreeMap<String, Vec<Tensor>>,
}

impl ModelWeights {
    /// Generate from the manifest (the NDIF "preloaded" path — no disk).
    pub fn generate(m: &Manifest) -> ModelWeights {
        let mut modules = BTreeMap::new();
        let embed = m.module("embed").expect("embed module");
        modules.insert(
            "embed".to_string(),
            embed
                .params()
                .map(|p| gen_param(&m.name, "embed", &p.name, &p.resolve(0)))
                .collect(),
        );
        let layer = m.module("layer").expect("layer module");
        for i in 0..m.n_layers {
            let key = format!("layer.{i}");
            modules.insert(
                key.clone(),
                layer
                    .params()
                    .map(|p| gen_param(&m.name, &key, &p.name, &p.resolve(0)))
                    .collect(),
            );
        }
        let head = m.module("lm_head").expect("lm_head module");
        modules.insert(
            "lm_head".to_string(),
            head.params()
                .map(|p| gen_param(&m.name, "lm_head", &p.name, &p.resolve(0)))
                .collect(),
        );
        ModelWeights { model: m.name.clone(), modules }
    }

    pub fn total_params(&self) -> usize {
        self.modules.values().flatten().map(Tensor::numel).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.total_params() * 4
    }

    // -- persistence (the HPC weight-loading path) ---------------------------

    const MAGIC: u32 = 0x4E_4E_53_57; // "NNSW"

    /// Write `weights.bin`: a flat, self-describing little-endian format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(self.total_bytes() + 4096);
        buf.extend_from_slice(&Self::MAGIC.to_le_bytes());
        let n: u32 = self.modules.values().map(|v| v.len() as u32).sum();
        buf.extend_from_slice(&n.to_le_bytes());
        for (key, tensors) in &self.modules {
            for (i, t) in tensors.iter().enumerate() {
                let name = format!("{key}#{i}");
                buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
                buf.extend_from_slice(name.as_bytes());
                buf.extend_from_slice(&(t.rank() as u32).to_le_bytes());
                for &d in t.dims() {
                    buf.extend_from_slice(&(d as u32).to_le_bytes());
                }
                // bulk-copy the f32 payload
                let bytes = unsafe {
                    std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.numel() * 4)
                };
                buf.extend_from_slice(bytes);
            }
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(&buf)?;
        Ok(())
    }

    /// Load `weights.bin` (the measured HPC setup path).
    pub fn load(path: &Path, model: &str) -> Result<ModelWeights> {
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        let mut off = 0usize;
        let take_u32 = |buf: &[u8], off: &mut usize| -> Result<u32> {
            if *off + 4 > buf.len() {
                return Err(anyhow!("truncated weights file"));
            }
            let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
            *off += 4;
            Ok(v)
        };
        if take_u32(&buf, &mut off)? != Self::MAGIC {
            return Err(anyhow!("bad magic in {path:?}"));
        }
        let n = take_u32(&buf, &mut off)? as usize;
        let mut modules: BTreeMap<String, Vec<Tensor>> = BTreeMap::new();
        for _ in 0..n {
            let name_len = take_u32(&buf, &mut off)? as usize;
            let name = std::str::from_utf8(&buf[off..off + name_len])?.to_string();
            off += name_len;
            let rank = take_u32(&buf, &mut off)? as usize;
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(take_u32(&buf, &mut off)? as usize);
            }
            let numel: usize = dims.iter().product();
            if off + numel * 4 > buf.len() {
                return Err(anyhow!("truncated tensor payload for {name}"));
            }
            let mut data = vec![0.0f32; numel];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    buf[off..].as_ptr(),
                    data.as_mut_ptr() as *mut u8,
                    numel * 4,
                );
            }
            off += numel * 4;
            let key = name
                .split_once('#')
                .ok_or_else(|| anyhow!("bad tensor name {name}"))?
                .0
                .to_string();
            modules.entry(key).or_default().push(Tensor::new(&dims, data));
        }
        Ok(ModelWeights { model: model.to_string(), modules })
    }

    /// Ensure `weights.bin` exists for a manifest; returns its path.
    pub fn ensure_on_disk(m: &Manifest) -> Result<std::path::PathBuf> {
        let path = m.dir.join("weights.bin");
        if !path.exists() {
            ModelWeights::generate(m).save(&path)?;
        }
        Ok(path)
    }

    // -- tensor-parallel slicing (mirror of python shard_layer_weights) ------

    /// Slice one layer's weights into per-shard (attn_args, mlp_args).
    ///
    /// Layout contract (layer param order):
    /// `[ln1_g, ln1_b, wq, wk, wv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2]`
    pub fn shard_layer(&self, layer_key: &str, shards: usize) -> Vec<(Vec<Tensor>, Vec<Tensor>)> {
        let w = &self.modules[layer_key];
        assert_eq!(w.len(), 13, "unexpected layer param count");
        let (ln1_g, ln1_b, wq, wk, wv, wo, bo) =
            (&w[0], &w[1], &w[2], &w[3], &w[4], &w[5], &w[6]);
        let (ln2_g, ln2_b, w1, b1, w2, b2) = (&w[7], &w[8], &w[9], &w[10], &w[11], &w[12]);
        let d = wq.dims()[0];
        let f = w1.dims()[1];
        let (ds, fs) = (d / shards, f / shards);
        (0..shards)
            .map(|s| {
                let (cs, ce) = (s * ds, (s + 1) * ds);
                let col = [Range1::all(), Range1::new(cs, ce)];
                let bo_s = if s == 0 { bo.clone() } else { Tensor::zeros(bo.dims()) };
                let attn = vec![
                    ln1_g.clone(),
                    ln1_b.clone(),
                    wq.slice(&col),
                    wk.slice(&col),
                    wv.slice(&col),
                    wo.slice(&[Range1::new(cs, ce)]),
                    bo_s,
                ];
                let (hs, he) = (s * fs, (s + 1) * fs);
                let b2_s = if s == 0 { b2.clone() } else { Tensor::zeros(b2.dims()) };
                let mlp = vec![
                    ln2_g.clone(),
                    ln2_b.clone(),
                    w1.slice(&[Range1::all(), Range1::new(hs, he)]),
                    b1.slice(&[Range1::new(hs, he)]),
                    w2.slice(&[Range1::new(hs, he)]),
                    b2_s,
                ];
                (attn, mlp)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::artifacts_dir;

    fn tiny() -> Manifest {
        Manifest::load(&artifacts_dir(), "tiny-sim").unwrap()
    }

    #[test]
    fn generation_is_deterministic_and_schema_shaped() {
        let m = tiny();
        let a = ModelWeights::generate(&m);
        let b = ModelWeights::generate(&m);
        assert_eq!(a.modules.len(), 2 + m.n_layers);
        for (k, ts) in &a.modules {
            for (i, t) in ts.iter().enumerate() {
                assert_eq!(t.data(), b.modules[k][i].data(), "{k}#{i}");
            }
        }
        // layer weights differ across layers
        assert_ne!(a.modules["layer.0"][2].data(), a.modules["layer.1"][2].data());
    }

    #[test]
    fn gains_ones_biases_zeros() {
        let m = tiny();
        let w = ModelWeights::generate(&m);
        let layer = m.module("layer").unwrap();
        for (spec, t) in layer.params().zip(&w.modules["layer.0"]) {
            if is_gain(&spec.name) {
                assert!(t.data().iter().all(|&v| v == 1.0), "{}", spec.name);
            }
            if is_bias(&spec.name) {
                assert!(t.data().iter().all(|&v| v == 0.0), "{}", spec.name);
            }
        }
    }

    #[test]
    fn save_load_round_trip() {
        let m = tiny();
        let w = ModelWeights::generate(&m);
        let tmp = std::env::temp_dir().join("nnscope_test_weights.bin");
        w.save(&tmp).unwrap();
        let r = ModelWeights::load(&tmp, "tiny-sim").unwrap();
        assert_eq!(w.total_params(), r.total_params());
        for (k, ts) in &w.modules {
            for (i, t) in ts.iter().enumerate() {
                assert_eq!(t.dims(), r.modules[k][i].dims());
                assert_eq!(t.data(), r.modules[k][i].data());
            }
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn param_count_matches_manifest() {
        let m = tiny();
        let w = ModelWeights::generate(&m);
        assert_eq!(w.total_params(), m.param_count);
    }

    #[test]
    fn shard_slicing_shapes() {
        let m = tiny();
        let w = ModelWeights::generate(&m);
        let shards = w.shard_layer("layer.0", 2);
        assert_eq!(shards.len(), 2);
        let (attn, mlp) = &shards[0];
        assert_eq!(attn[2].dims(), &[m.d_model, m.d_model / 2]); // wq_s
        assert_eq!(attn[5].dims(), &[m.d_model / 2, m.d_model]); // wo_s
        assert_eq!(mlp[2].dims(), &[m.d_model, m.d_ff / 2]); // w1_s
        // shard columns reassemble the original
        let full = &w.modules["layer.0"][2];
        let s0 = &shards[0].0[2];
        let s1 = &shards[1].0[2];
        let cat = Tensor::concat(&[s0, s1], 1);
        assert_eq!(&cat, full);
    }

    #[test]
    fn weight_values_match_python_contract() {
        // first values of tiny-sim/layer.0/wq with a=0.02*sqrt(3); the
        // python side generates the identical stream (see weights.py).
        let t = gen_param("tiny-sim", "layer.0", "wq", &[2, 2]);
        let mut rng = Prng::from_name("tiny-sim/layer.0/wq");
        let mut expect = [0.0f32; 4];
        rng.fill_uniform_sym(&mut expect, WEIGHT_STD * 3.0_f64.sqrt());
        assert_eq!(t.data(), expect);
    }
}
