//! Workload generation: the synthetic IOI-style dataset and load-test
//! request sampling.
//!
//! The paper's evaluation workload is "a single batch of 32 examples from
//! the Indirect Object Identification (IOI) dataset" (Wang et al., 2022)
//! with activation patching at a chosen layer, measured by logit
//! difference. Real IOI prompts need a real tokenizer; our substitute
//! (DESIGN.md §3) keeps the structure that matters: fixed-template token
//! sequences over the model vocabulary in which two "name" tokens appear,
//! the correct continuation is the indirect object (the name NOT repeated
//! before the final position), and patching a hidden state from a
//! counterfactual prompt flips the prediction.

use crate::tensor::Tensor;
use crate::util::Prng;

/// One IOI-style example: a base prompt, a counterfactual (source) prompt
/// with the names swapped, and the answer/foil token ids.
#[derive(Clone, Debug)]
pub struct IoiExample {
    pub base: Vec<f32>,
    pub source: Vec<f32>,
    /// indirect object (correct answer) token id
    pub target: usize,
    /// subject (incorrect) token id
    pub foil: usize,
}

/// A batch of IOI examples plus tensors shaped for the model.
pub struct IoiBatch {
    pub examples: Vec<IoiExample>,
    pub seq: usize,
}

/// Template token ids (small reserved region of the vocab acts as the
/// "grammar"; names are drawn from the rest).
const T_AND: usize = 1;
const T_WENT: usize = 2;
const T_TO: usize = 3;
const T_THE: usize = 4;
const T_STORE: usize = 5;
const T_GAVE: usize = 6;
const T_A: usize = 7;
const T_DRINK: usize = 8;
const RESERVED: usize = 16;

impl IoiBatch {
    /// Generate `n` examples for a model with the given vocab/seq.
    pub fn generate(n: usize, vocab: usize, seq: usize, seed: u64) -> IoiBatch {
        assert!(vocab > RESERVED + 2, "vocab too small for IOI templates");
        let mut rng = Prng::new(seed);
        let examples = (0..n)
            .map(|_| {
                // two distinct names
                let name_a = RESERVED + rng.range(0, vocab - RESERVED);
                let mut name_b = RESERVED + rng.range(0, vocab - RESERVED);
                while name_b == name_a {
                    name_b = RESERVED + rng.range(0, vocab - RESERVED);
                }
                // "A and B went to the store, B gave a drink to" → A
                let mk = |s1: usize, s2: usize, subj: usize| -> Vec<f32> {
                    let mut t = vec![
                        s1, T_AND, s2, T_WENT, T_TO, T_THE, T_STORE, subj, T_GAVE, T_A, T_DRINK,
                        T_TO,
                    ];
                    t.resize(seq, 0); // pad with token 0
                    // right-align so "to" is the last position (next-token
                    // prediction target = indirect object)
                    t.rotate_right(seq - 12);
                    t.into_iter().map(|x| x as f32).collect()
                };
                IoiExample {
                    base: mk(name_a, name_b, name_b),
                    source: mk(name_b, name_a, name_a),
                    target: name_a,
                    foil: name_b,
                }
            })
            .collect();
        IoiBatch { examples, seq }
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// `[n, seq]` token tensor of the base prompts.
    pub fn base_tokens(&self) -> Tensor {
        self.tokens(|e| &e.base)
    }

    /// `[n, seq]` token tensor of the counterfactual prompts.
    pub fn source_tokens(&self) -> Tensor {
        self.tokens(|e| &e.source)
    }

    fn tokens(&self, f: impl Fn(&IoiExample) -> &Vec<f32>) -> Tensor {
        let n = self.examples.len();
        let mut data = Vec::with_capacity(n * self.seq);
        for e in &self.examples {
            data.extend_from_slice(f(e));
        }
        Tensor::new(&[n, self.seq], data)
    }

    /// Interleaved batch [source_0, base_0, source_1, base_1, ...] as used
    /// by the classic single-pass patching recipe (source row feeds the
    /// patch for the base row).
    pub fn interleaved_tokens(&self) -> Tensor {
        let n = self.examples.len();
        let mut data = Vec::with_capacity(2 * n * self.seq);
        for e in &self.examples {
            data.extend_from_slice(&e.source);
            data.extend_from_slice(&e.base);
        }
        Tensor::new(&[2 * n, self.seq], data)
    }
}

/// Load-test request (Fig. 9): a short prompt and a random layer whose
/// output the user saves.
#[derive(Clone, Debug)]
pub struct LoadTestRequest {
    pub tokens: Vec<f32>,
    pub layer: usize,
}

/// Sample a Fig. 9-style request: "a prompt containing up to 24 tokens
/// that accesses and saves the output of a layer selected uniformly at
/// random".
pub fn load_test_request(rng: &mut Prng, vocab: usize, seq: usize, n_layers: usize) -> LoadTestRequest {
    let len = rng.range(1, 24.min(seq) + 1);
    let mut tokens = vec![0.0f32; seq];
    for t in tokens.iter_mut().take(len) {
        *t = rng.range(1, vocab) as f32;
    }
    LoadTestRequest { tokens, layer: rng.range(0, n_layers) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ioi_shapes_and_determinism() {
        let a = IoiBatch::generate(8, 512, 32, 42);
        let b = IoiBatch::generate(8, 512, 32, 42);
        assert_eq!(a.len(), 8);
        assert_eq!(a.base_tokens().dims(), &[8, 32]);
        assert_eq!(a.base_tokens().data(), b.base_tokens().data());
        assert_eq!(a.interleaved_tokens().dims(), &[16, 32]);
    }

    #[test]
    fn ioi_names_swap_between_base_and_source() {
        let batch = IoiBatch::generate(4, 512, 32, 7);
        for e in &batch.examples {
            assert_ne!(e.target, e.foil);
            assert!(e.target >= RESERVED && e.foil >= RESERVED);
            // base ends with "... subj gave a drink to" where subj == foil
            let last = |v: &Vec<f32>| v[v.len() - 5] as usize;
            assert_eq!(last(&e.base), e.foil);
            assert_eq!(last(&e.source), e.target);
            // final token is T_TO in both
            assert_eq!(*e.base.last().unwrap() as usize, T_TO);
            assert_eq!(*e.source.last().unwrap() as usize, T_TO);
        }
    }

    #[test]
    fn ioi_tokens_within_vocab() {
        let batch = IoiBatch::generate(16, 64, 16, 1);
        for e in &batch.examples {
            assert!(e.base.iter().all(|&t| (t as usize) < 64));
            assert!(e.source.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn load_test_request_bounds() {
        let mut rng = Prng::new(3);
        for _ in 0..100 {
            let r = load_test_request(&mut rng, 512, 32, 8);
            assert_eq!(r.tokens.len(), 32);
            assert!(r.layer < 8);
            assert!(r.tokens.iter().all(|&t| (t as usize) < 512));
        }
    }
}
