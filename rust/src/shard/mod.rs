// shard module folded into models::runner::forward_sharded — kept as re-export site
//! Tensor-parallel shard execution lives in [`crate::models::runner`]
//! (`forward_sharded`): S shard workers execute per-shard partial-layer
//! artifacts and the coordinator all-reduces. This module re-exports the
//! entry points for discoverability.
pub use crate::models::runner::ModelRunner;
