//! Pluggable routing policies over the replica registry.
//!
//! The router answers one question: *given the non-dead replicas hosting a
//! model, which one takes the next request?* Three policies are provided:
//!
//! * **round-robin** — rotate through candidates; the paper's implicit
//!   baseline for stateless front-ends;
//! * **least-loaded** — minimize `queue_depth + inflight`, where
//!   `queue_depth` comes from replica heartbeats
//!   ([`crate::scheduler::ServiceMetrics`]) and `inflight` is the
//!   coordinator's own fresher dispatch accounting;
//! * **latency-aware** — prefer the replica with the smallest advertised
//!   [`crate::netsim::NetSim`] link latency, breaking ties by load.
//!
//! All policies prefer [`Health::Alive`] replicas and fall back to
//! [`Health::Degraded`] ones only when no alive candidate remains.
//! Failover (retrying a request on the next replica when one dies
//! mid-request) lives in [`crate::coordinator::api`]; the router only
//! supports it by honoring an exclusion list of already-failed replicas.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::registry::{Health, Replica};

/// Health of the best candidate still in the pool — the router only mixes
/// equally-healthy replicas within one pick.
fn best_health(pool: &[&Replica]) -> Option<Health> {
    pool.iter().map(|r| r.health).min()
}

/// Total order over non-negative metric values (NaN sorts last so a
/// corrupt observation never wins a pick).
fn cmp_f64(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap_or_else(|| match (a.is_nan(), b.is_nan()) {
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        _ => std::cmp::Ordering::Equal,
    })
}

/// Routing policy selector (CLI: `--policy <name>`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    LatencyAware,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "round-robin" | "rr" => Some(Policy::RoundRobin),
            "least-loaded" | "ll" => Some(Policy::LeastLoaded),
            "latency-aware" | "latency" => Some(Policy::LatencyAware),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::LatencyAware => "latency-aware",
        }
    }
}

/// Stateless-per-request replica chooser (the round-robin cursor is the
/// only internal state).
pub struct Router {
    pub policy: Policy,
    rr: AtomicUsize,
}

impl Router {
    pub fn new(policy: Policy) -> Router {
        Router { policy, rr: AtomicUsize::new(0) }
    }

    /// Choose a replica among `candidates` (pre-filtered to non-dead
    /// replicas hosting the model, as produced by
    /// [`super::registry::Registry::candidates`]), skipping ids in
    /// `exclude` — replicas that already failed this request.
    pub fn pick(&self, candidates: &[Replica], exclude: &[String]) -> Option<Replica> {
        let pool: Vec<&Replica> = candidates
            .iter()
            .filter(|r| !exclude.iter().any(|e| e == &r.id))
            .collect();
        let best = best_health(&pool)?;
        let pool: Vec<&Replica> = pool.into_iter().filter(|r| r.health == best).collect();
        let chosen = match self.policy {
            Policy::RoundRobin => pool[self.rr.fetch_add(1, Ordering::Relaxed) % pool.len()],
            // load first; ties split by the heartbeat-observed e2e p95 so
            // equally-queued replicas prefer the one actually answering
            // faster (a replica with no observation yet reports 0 and
            // stays first pick, as before this field existed)
            Policy::LeastLoaded => pool
                .iter()
                .copied()
                .min_by(|a, b| {
                    a.load()
                        .cmp(&b.load())
                        .then_with(|| cmp_f64(a.p95_ms, b.p95_ms))
                        .then_with(|| a.routed.cmp(&b.routed))
                        .then_with(|| a.id.cmp(&b.id))
                })
                .expect("non-empty pool"),
            Policy::LatencyAware => pool
                .iter()
                .copied()
                .min_by(|a, b| {
                    cmp_f64(a.latency_s, b.latency_s)
                        .then_with(|| a.load().cmp(&b.load()))
                        .then_with(|| cmp_f64(a.p95_ms, b.p95_ms))
                        .then_with(|| a.id.cmp(&b.id))
                })
                .expect("non-empty pool"),
        };
        Some(chosen.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn replica(id: &str, health: Health, load: usize, latency_s: f64) -> Replica {
        Replica {
            id: id.to_string(),
            addr: "127.0.0.1:1".parse().unwrap(),
            models: vec!["m".into()],
            health,
            last_heartbeat: Instant::now(),
            queue_depth: load,
            inflight: 0,
            completed: 0,
            failed: 0,
            routed: 0,
            consecutive_failures: 0,
            latency_s,
            p95_ms: 0.0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(Policy::RoundRobin);
        let pool = vec![
            replica("a", Health::Alive, 0, 0.0),
            replica("b", Health::Alive, 0, 0.0),
        ];
        let picks: Vec<String> = (0..4).map(|_| r.pick(&pool, &[]).unwrap().id).collect();
        assert_eq!(picks, vec!["a", "b", "a", "b"]);
    }

    #[test]
    fn least_loaded_picks_min_queue() {
        let r = Router::new(Policy::LeastLoaded);
        let pool = vec![
            replica("a", Health::Alive, 5, 0.0),
            replica("b", Health::Alive, 1, 0.0),
            replica("c", Health::Alive, 3, 0.0),
        ];
        assert_eq!(r.pick(&pool, &[]).unwrap().id, "b");
    }

    #[test]
    fn least_loaded_ties_break_on_observed_p95() {
        let r = Router::new(Policy::LeastLoaded);
        let mut slow = replica("slow", Health::Alive, 2, 0.0);
        slow.p95_ms = 80.0;
        let mut fast = replica("fast", Health::Alive, 2, 0.0);
        fast.p95_ms = 8.0;
        // equal load: the replica with the better observed p95 wins
        assert_eq!(r.pick(&[slow.clone(), fast.clone()], &[]).unwrap().id, "fast");
        // load still dominates: a shorter queue beats a better p95
        slow.queue_depth = 1;
        assert_eq!(r.pick(&[slow, fast], &[]).unwrap().id, "slow");
    }

    #[test]
    fn latency_aware_prefers_near_replica() {
        let r = Router::new(Policy::LatencyAware);
        let pool = vec![
            replica("far", Health::Alive, 0, 0.060),
            replica("near", Health::Alive, 0, 0.002),
        ];
        assert_eq!(r.pick(&pool, &[]).unwrap().id, "near");
    }

    #[test]
    fn alive_preferred_over_degraded() {
        let r = Router::new(Policy::LeastLoaded);
        // degraded replica is idle, alive one is loaded — alive still wins
        let pool = vec![
            replica("tired", Health::Degraded, 0, 0.0),
            replica("busy", Health::Alive, 9, 0.0),
        ];
        assert_eq!(r.pick(&pool, &[]).unwrap().id, "busy");
        // …until the alive one is excluded (it failed this request)
        assert_eq!(r.pick(&pool, &["busy".to_string()]).unwrap().id, "tired");
    }

    #[test]
    fn exhausted_pool_returns_none() {
        let r = Router::new(Policy::RoundRobin);
        let pool = vec![replica("a", Health::Alive, 0, 0.0)];
        assert!(r.pick(&pool, &["a".to_string()]).is_none());
        assert!(r.pick(&[], &[]).is_none());
    }
}
