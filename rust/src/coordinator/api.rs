//! Coordinator HTTP front: one address for a whole NDIF fleet.
//!
//! The coordinator mirrors the single-server NDIF surface (`POST
//! /v1/trace`, `GET /v1/result/<id>`, `POST /v1/session`, `GET
//! /v1/models`) so existing clients and examples work unchanged against a
//! fleet, and adds fleet management:
//!
//! * `POST /v1/fleet/register` / `deregister` — replica lifecycle;
//! * `POST /v1/fleet/heartbeat` — load snapshots for least-loaded routing;
//! * `GET /v1/fleet/status` — registry view: health, load, routing counts.
//!
//! Request lifecycle: an accepted trace is parked as pending in a
//! coordinator-side [`ObjectStore`], a routing worker picks a replica via
//! the configured [`Policy`], proxies the submit, and long-polls the
//! replica for the result. If the replica dies mid-request (connect
//! failure, lost result state), the worker marks it failed in the registry
//! and *resubmits the retained request body* to the next candidate —
//! bounded by `max_retries` — so a replica crash never loses an accepted
//! request. A monitor thread probes replicas between heartbeats so dead
//! deployments are evicted from routing even when they never said goodbye.
//!
//! One deliberate contract difference from a single server: because the
//! coordinator accepts (202) before routing, replica-side rejections that
//! a single server reports at submit time (401 auth, 400 validation)
//! surface here through `GET /v1/result/<id>` as a 500 whose error message
//! embeds the replica's status and body. [`crate::client::remote`] handles
//! both shapes identically.
//!
//! **Session-state stickiness:** a `POST /v1/session` naming a persistent
//! session (`"session": "<id>"`) pins that session to the replica that
//! serves its first request — the state tensors live in that replica's
//! memory, so follow-up bundles must land there. If the pinned replica
//! dies (or the request to it fails at transport level), the coordinator
//! does NOT fail over — the state is gone with the replica — it unpins the
//! session and answers `503 {"error": …, "retryable": true}` so the client
//! can restart the session from scratch instead of hanging or silently
//! training against a replica that never saw its parameters.

use std::collections::{BTreeMap, HashMap};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::json::{parse, Json};
use crate::scheduler::LoadSnapshot;
use crate::server::admission::{AdmissionControl, Decision, RateLimit};
use crate::server::api::{parse_result_path, throttle_response};
use crate::server::http::{self, Chunk, Handler, HttpServer, Request, Response};
use crate::server::store::{Entry, ObjectStore};
use crate::threadpool::ThreadPool;
use crate::util::failpoint::{self, FailAction};

use super::registry::{Health, HealthPolicy, Registry, Replica};
use super::router::{Policy, Router};

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Bind address; use port 0 for ephemeral.
    pub addr: String,
    /// HTTP worker threads.
    pub workers: usize,
    /// Routing worker threads — the cap on concurrently proxied traces
    /// (each routed request occupies one worker while it long-polls its
    /// replica; excess submissions queue, giving backpressure instead of
    /// unbounded thread growth).
    pub routing_workers: usize,
    /// Routing policy.
    pub policy: Policy,
    /// Additional replica attempts after the first fails at transport level.
    pub max_retries: usize,
    /// Cadence of the active health/metrics probe.
    pub probe_interval: Duration,
    /// Heartbeat-age / failure thresholds for health derivation.
    pub health: HealthPolicy,
    /// Upper bound on one routed request (per replica attempt).
    pub request_timeout: Duration,
    /// Socket-level connect/read/write bound for coordinator→replica calls
    /// (probes, submits, result polls) — a hung replica costs at most this
    /// per exchange instead of wedging a routing worker or the monitor.
    /// Result polls ask the replica to hold for at most half this value.
    pub io_timeout: Duration,
    /// Idle bound on session→replica pins: pins untouched for longer are
    /// swept (align with the replicas' session-state TTL so the pin map
    /// stays bounded and pins don't outlive the state they point at).
    pub session_pin_ttl: Duration,
    /// Statically configured replicas: `host:port` or `host:port@latency_s`
    /// (the latency a [`crate::netsim::NetSim`] profile would charge).
    pub replicas: Vec<String>,
    /// Front-door per-tenant token-bucket rate limit (keyed by auth token,
    /// anonymous traffic pooling), applied BEFORE routing so an overdrawn
    /// tenant is throttled once at the fleet edge instead of burning a
    /// routing worker per rejected request. `None` = unlimited.
    pub rate_limit: Option<RateLimit>,
}

impl CoordinatorConfig {
    pub fn local() -> CoordinatorConfig {
        CoordinatorConfig {
            addr: "127.0.0.1:0".into(),
            workers: 8,
            routing_workers: 64,
            policy: Policy::LeastLoaded,
            max_retries: 3,
            probe_interval: Duration::from_millis(250),
            health: HealthPolicy::default(),
            request_timeout: Duration::from_secs(300),
            io_timeout: Duration::from_secs(10),
            session_pin_ttl: Duration::from_secs(600),
            replicas: Vec::new(),
            rate_limit: None,
        }
    }
}

/// Routing state shared with worker jobs and the monitor thread — kept
/// apart from [`CoordState`] so queued routing jobs never hold the pool
/// that runs them (which would self-join on the last drop).
struct RoutingCore {
    registry: Registry,
    router: Router,
    max_retries: usize,
    request_timeout: Duration,
    io_timeout: Duration,
}

/// One persistent session's pin: the replica holding its state, plus the
/// last time the pin was used (for TTL sweeping).
struct Pin {
    replica: String,
    at: Instant,
}

struct CoordState {
    core: Arc<RoutingCore>,
    store: Arc<ObjectStore>,
    next_id: AtomicU64,
    routing: ThreadPool,
    /// Persistent-session pinning: session id → replica holding its
    /// server-side state. Entries are dropped on DELETE, on observed
    /// replica death, or after `session_pin_ttl` idle — NOT on transient
    /// transport errors (the replica may be alive with the state intact).
    sessions: Mutex<HashMap<String, Pin>>,
    session_pin_ttl: Duration,
    /// Finished routed-request traces (`GET /v1/debug/requests`): the
    /// coordinator-side view — trace id, model, attempts, outcome — of
    /// the last N requests, written once per finished request.
    ring: crate::obs::TraceRing,
    /// Front-door per-tenant rate limiting (`None` = unlimited).
    admission: Option<AdmissionControl>,
    /// Requests throttled 429 at the front door.
    throttled: AtomicU64,
}

impl CoordState {
    /// Sweep idle pins, then return the replica id pinned for `sid`.
    fn pinned_replica(&self, sid: &str) -> Option<String> {
        let mut m = self.sessions.lock().unwrap();
        m.retain(|_, p| p.at.elapsed() <= self.session_pin_ttl);
        m.get(sid).map(|p| p.replica.clone())
    }
}

/// A running fleet coordinator.
pub struct Coordinator {
    http: HttpServer,
    state: Arc<CoordState>,
    stop: Arc<AtomicBool>,
    monitor: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Register any static replicas, start serving, then start the monitor
    /// thread (bind-first so a failed bind leaves no stray thread behind).
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // keep health thresholds compatible with the probe cadence:
        // statically configured replicas are kept alive only by probes, so
        // aging them out faster than the monitor refreshes them would flap
        // healthy replicas between Alive and Dead
        let mut health = cfg.health;
        health.degraded_after = health.degraded_after.max(cfg.probe_interval * 3);
        health.dead_after = health.dead_after.max(cfg.probe_interval * 8);
        let core = Arc::new(RoutingCore {
            registry: Registry::new(health),
            router: Router::new(cfg.policy),
            max_retries: cfg.max_retries,
            request_timeout: cfg.request_timeout,
            io_timeout: cfg.io_timeout,
        });
        for spec in &cfg.replicas {
            let (addr_s, latency_s) = match spec.split_once('@') {
                Some((a, l)) => (
                    a,
                    l.parse::<f64>()
                        .with_context(|| format!("replica latency in '{spec}'"))?,
                ),
                None => (spec.as_str(), 0.0),
            };
            let addr: SocketAddr = addr_s
                .parse()
                .with_context(|| format!("replica address '{spec}'"))?;
            // learn hosted models now if the replica is already up; the
            // monitor keeps trying otherwise
            let models = probe_models(addr, cfg.io_timeout).unwrap_or_default();
            core.registry.register(addr, models, latency_s, None);
        }
        let state = Arc::new(CoordState {
            core: Arc::clone(&core),
            store: Arc::new(ObjectStore::new()),
            next_id: AtomicU64::new(1),
            routing: ThreadPool::new(cfg.routing_workers),
            sessions: Mutex::new(HashMap::new()),
            session_pin_ttl: cfg.session_pin_ttl,
            ring: crate::obs::TraceRing::new(256),
            admission: cfg.rate_limit.map(AdmissionControl::new),
            throttled: AtomicU64::new(0),
        });
        let s2 = Arc::clone(&state);
        let handler: Handler = Arc::new(move |req| route(&s2, req));
        let http = HttpServer::bind(&cfg.addr, cfg.workers, handler)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (core2, stop2, interval) = (core, Arc::clone(&stop), cfg.probe_interval);
        let monitor = std::thread::Builder::new()
            .name("ndif-coord-monitor".into())
            .spawn(move || monitor_loop(&core2, &stop2, interval))?;
        Ok(Coordinator { http, state, stop, monitor: Some(monitor) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.http.addr()
    }

    /// Registry snapshot (tests, `coordinate` CLI).
    pub fn replicas(&self) -> Vec<Replica> {
        self.state.core.registry.snapshot()
    }

    /// Stop the monitor and the HTTP front. Routed requests still in
    /// flight finish on the routing pool when the state drops.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.monitor.take() {
            let _ = t.join();
        }
        self.http.shutdown();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Replica-side helpers (used by NdifServer self-registration)
// ---------------------------------------------------------------------------

/// Bound on replica→coordinator management calls: a hung coordinator must
/// not wedge a replica's heartbeat thread (its shutdown joins that thread).
const FLEET_CALL_TIMEOUT: Duration = Duration::from_secs(5);

/// Register `advertise` as a replica serving `models` with the coordinator.
/// Returns the assigned replica id. Pass the previous `id` to reclaim an
/// entry after the coordinator answered a heartbeat with 404.
pub fn register_replica(
    coordinator: SocketAddr,
    advertise: SocketAddr,
    models: &[String],
    latency_s: f64,
    id: Option<&str>,
) -> Result<String> {
    let mut fields = vec![
        ("addr", Json::from(advertise.to_string())),
        (
            "models",
            Json::Array(models.iter().map(|m| Json::from(m.as_str())).collect()),
        ),
        ("latency_s", Json::from(latency_s)),
    ];
    if let Some(i) = id {
        fields.push(("id", Json::from(i)));
    }
    let payload = Json::obj(fields).to_string();
    let (status, body) = http::http_request_timeout(
        coordinator,
        "POST",
        "/v1/fleet/register",
        payload.as_bytes(),
        &[("Content-Type", "application/json")],
        FLEET_CALL_TIMEOUT,
    )?;
    if status != 200 {
        return Err(anyhow!(
            "coordinator register failed ({status}): {}",
            String::from_utf8_lossy(&body)
        ));
    }
    parse(std::str::from_utf8(&body)?)?
        .get("id")
        .as_str()
        .map(String::from)
        .ok_or_else(|| anyhow!("register response missing id"))
}

/// Push one heartbeat with a load snapshot and the replica's observed
/// end-to-end p95 (ms; `0.0` = nothing observed yet); returns the HTTP
/// status (404 means the coordinator forgot us — re-register).
pub fn send_heartbeat(
    coordinator: SocketAddr,
    id: &str,
    load: &LoadSnapshot,
    p95_ms: f64,
) -> Result<u16> {
    let payload = Json::obj(vec![
        ("id", Json::from(id)),
        ("queue_depth", Json::from(load.queue_depth)),
        ("completed", Json::from(load.completed as i64)),
        ("failed", Json::from(load.failed as i64)),
        ("p95_ms", Json::from(p95_ms)),
    ])
    .to_string();
    let (status, _) = http::http_request_timeout(
        coordinator,
        "POST",
        "/v1/fleet/heartbeat",
        payload.as_bytes(),
        &[("Content-Type", "application/json")],
        FLEET_CALL_TIMEOUT,
    )?;
    Ok(status)
}

/// Graceful goodbye (best-effort; crashes simply stop heartbeating).
pub fn deregister_replica(coordinator: SocketAddr, id: &str) -> Result<()> {
    let payload = Json::obj(vec![("id", Json::from(id))]).to_string();
    let _ = http::http_request_timeout(
        coordinator,
        "POST",
        "/v1/fleet/deregister",
        payload.as_bytes(),
        &[("Content-Type", "application/json")],
        FLEET_CALL_TIMEOUT,
    )?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Monitor
// ---------------------------------------------------------------------------

fn monitor_loop(core: &Arc<RoutingCore>, stop: &Arc<AtomicBool>, interval: Duration) {
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(interval);
        if stop.load(Ordering::SeqCst) {
            break;
        }
        for rep in core.registry.snapshot() {
            match http::get_timeout(rep.addr, "/v1/metrics", core.io_timeout) {
                Ok((200, body)) => {
                    let (queue_depth, completed, failed) = parse_metrics(&body);
                    let p95_ms = parse_metrics_p95_ms(&body);
                    core.registry.heartbeat(&rep.id, queue_depth, completed, failed, p95_ms);
                    if rep.models.is_empty() {
                        if let Ok(models) = probe_models(rep.addr, core.io_timeout) {
                            core.registry.set_models(&rep.id, models);
                        }
                    }
                }
                _ => core.registry.probe_failed(&rep.id),
            }
        }
    }
}

/// Sum the per-model counters of a replica `/v1/metrics` payload.
/// (Underscore-prefixed process-wide keys carry no counters, so they
/// contribute zero and need no special casing.)
fn parse_metrics(body: &[u8]) -> (usize, u64, u64) {
    let Ok(s) = std::str::from_utf8(body) else { return (0, 0, 0) };
    let Ok(j) = parse(s) else { return (0, 0, 0) };
    let (mut queue_depth, mut completed, mut failed) = (0usize, 0u64, 0u64);
    if let Some(models) = j.as_object() {
        for m in models.values() {
            queue_depth += m.get("queue_depth").as_usize().unwrap_or(0);
            completed += m.get("completed").as_i64().unwrap_or(0).max(0) as u64;
            failed += m.get("failed").as_i64().unwrap_or(0).max(0) as u64;
        }
    }
    (queue_depth, completed, failed)
}

/// Merge the per-model e2e latency histograms of a replica `/v1/metrics`
/// payload and return the merged p95 in milliseconds (`0.0` when the
/// replica exposes no latency data — observability off or no traffic).
fn parse_metrics_p95_ms(body: &[u8]) -> f64 {
    let Ok(s) = std::str::from_utf8(body) else { return 0.0 };
    let Ok(j) = parse(s) else { return 0.0 };
    let mut merged = crate::obs::HistSnapshot::default();
    if let Some(models) = j.as_object() {
        for (name, m) in models {
            if name.starts_with('_') {
                continue;
            }
            if let Some(h) = crate::obs::HistSnapshot::from_json(m.get("latency").get("e2e")) {
                merged.merge(&h);
            }
        }
    }
    if merged.count == 0 {
        0.0
    } else {
        merged.percentile(0.95) * 1e3
    }
}

fn probe_models(addr: SocketAddr, timeout: Duration) -> Result<Vec<String>> {
    let (status, body) = http::get_timeout(addr, "/v1/models", timeout)?;
    if status != 200 {
        return Err(anyhow!("models probe returned {status}"));
    }
    Ok(parse(std::str::from_utf8(&body)?)?
        .get("models")
        .as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|m| m.get("name").as_str().map(String::from))
        .collect())
}

// ---------------------------------------------------------------------------
// HTTP routing
// ---------------------------------------------------------------------------

fn route(state: &Arc<CoordState>, req: Request) -> Response {
    // front-door rate limit on work-submitting endpoints, before any
    // parsing or routing-worker dispatch. A replica-side 429 is relayed
    // as-is further down — never failed over: the tenant's bucket is just
    // as overdrawn at the next replica.
    if matches!(
        (req.method.as_str(), req.path.as_str()),
        ("POST", "/v1/trace") | ("POST", "/v1/session") | ("POST", "/v1/stream")
    ) {
        if let Some(adm) = &state.admission {
            let tenant = req.header("x-ndif-auth").unwrap_or("anon");
            if let Decision::Throttle { retry_after } = adm.check(tenant) {
                state.throttled.fetch_add(1, Ordering::Relaxed);
                return throttle_response(retry_after);
            }
        }
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Response::text(200, "ok"),
        ("GET", "/v1/fleet/status") => status_endpoint(state),
        ("POST", "/v1/fleet/register") => register_endpoint(state, &req),
        ("POST", "/v1/fleet/deregister") => deregister_endpoint(state, &req),
        ("POST", "/v1/fleet/heartbeat") => heartbeat_endpoint(state, &req),
        ("GET", path) if path == "/v1/fleet/metrics" || path.starts_with("/v1/fleet/metrics?") => {
            fleet_metrics_endpoint(state, path)
        }
        ("GET", "/v1/fleet/hotops") => fleet_hotops_endpoint(state),
        ("GET", "/v1/debug/requests") => debug_requests_endpoint(state),
        ("GET", "/v1/models") => models_endpoint(state),
        ("POST", "/v1/trace") => trace_endpoint(state, &req),
        ("POST", "/v1/session") => session_endpoint(state, &req),
        ("POST", "/v1/stream") => stream_endpoint(state, &req),
        ("GET", path) if path.starts_with("/v1/result/") => result_endpoint(state, path),
        ("GET", path) if path.starts_with("/v1/session/") => {
            session_proxy_endpoint(state, &req, "GET")
        }
        ("DELETE", path) if path.starts_with("/v1/session/") => {
            session_proxy_endpoint(state, &req, "DELETE")
        }
        _ => Response::not_found(),
    }
}

fn body_json(req: &Request) -> Result<Json, Response> {
    req.body_str()
        .map_err(|e| Response::bad_request(&e.to_string()))
        .and_then(|s| parse(s).map_err(|e| Response::bad_request(&e.to_string())))
}

fn register_endpoint(state: &Arc<CoordState>, req: &Request) -> Response {
    let j = match body_json(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(addr_s) = j.get("addr").as_str() else {
        return Response::bad_request("register missing addr");
    };
    let Ok(addr) = addr_s.parse::<SocketAddr>() else {
        return Response::bad_request(&format!("invalid replica addr '{addr_s}'"));
    };
    let models: Vec<String> = j
        .get("models")
        .as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|m| m.as_str().map(String::from))
        .collect();
    let latency_s = j.get("latency_s").as_f64().unwrap_or(0.0);
    let id = state
        .core
        .registry
        .register(addr, models, latency_s, j.get("id").as_str());
    Response::json(200, Json::obj(vec![("id", Json::from(id))]).to_string())
}

fn deregister_endpoint(state: &Arc<CoordState>, req: &Request) -> Response {
    let j = match body_json(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(id) = j.get("id").as_str() else {
        return Response::bad_request("deregister missing id");
    };
    if state.core.registry.deregister(id) {
        Response::json(200, "{\"removed\":true}".into())
    } else {
        Response::json(404, "{\"error\":\"unknown replica id\"}".into())
    }
}

fn heartbeat_endpoint(state: &Arc<CoordState>, req: &Request) -> Response {
    let j = match body_json(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(id) = j.get("id").as_str() else {
        return Response::bad_request("heartbeat missing id");
    };
    let queue_depth = j.get("queue_depth").as_usize().unwrap_or(0);
    let completed = j.get("completed").as_i64().unwrap_or(0).max(0) as u64;
    let failed = j.get("failed").as_i64().unwrap_or(0).max(0) as u64;
    let p95_ms = j.get("p95_ms").as_f64().unwrap_or(0.0);
    if state.core.registry.heartbeat(id, queue_depth, completed, failed, p95_ms) {
        Response::json(200, "{\"ok\":true}".into())
    } else {
        Response::json(404, "{\"error\":\"unknown replica id\"}".into())
    }
}

fn status_endpoint(state: &Arc<CoordState>) -> Response {
    let replicas: Vec<Json> = state
        .core
        .registry
        .snapshot()
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("id", Json::from(r.id.as_str())),
                ("addr", Json::from(r.addr.to_string())),
                (
                    "models",
                    Json::Array(r.models.iter().map(|m| Json::from(m.as_str())).collect()),
                ),
                ("health", Json::from(r.health.as_str())),
                ("queue_depth", Json::from(r.queue_depth)),
                ("inflight", Json::from(r.inflight)),
                ("completed", Json::from(r.completed as i64)),
                ("failed", Json::from(r.failed as i64)),
                ("routed", Json::from(r.routed as i64)),
                ("consecutive_failures", Json::from(r.consecutive_failures as i64)),
                ("latency_s", Json::from(r.latency_s)),
                ("p95_ms", Json::from(r.p95_ms)),
                (
                    "heartbeat_age_ms",
                    Json::from(r.last_heartbeat.elapsed().as_millis() as i64),
                ),
            ])
        })
        .collect();
    Response::json(
        200,
        Json::obj(vec![
            ("policy", Json::from(state.core.router.policy.as_str())),
            ("throttled", Json::from(state.throttled.load(Ordering::Relaxed) as i64)),
            ("replicas", Json::Array(replicas)),
        ])
        .to_string(),
    )
}

/// `GET /v1/fleet/metrics`: fleet-wide latency percentiles by per-bucket
/// histogram merging.
///
/// The coordinator fans out to every non-dead replica's `/v1/metrics`,
/// sums the flat counters, and **merges the latency histograms bucket by
/// bucket** (legal because bucket boundaries are compile-time constants
/// fleet-wide, [`crate::obs::hist`]). Percentiles are then computed from
/// the merged counts with the same [`crate::obs::percentile_from_counts`]
/// every replica uses, so a fleet p95 is bit-identical to the p95 of the
/// concatenated per-replica observations — unlike the ad-hoc averaging of
/// per-replica percentiles (which is statistically meaningless).
///
/// Response shape per model: the summed counters plus a `"latency"`
/// object of merged histogram snapshots (e2e/queue_wait/exec/ttft); a
/// `"_fleet"` key carries the replica count consulted.
///
/// `?format=prometheus` renders the same merged histograms in Prometheus
/// text exposition via the replica's own formatter
/// ([`crate::obs::registry::prometheus_histogram`]), so fleet and replica
/// series are line-identical for identical counts; a
/// `nnscope_fleet_replicas` gauge carries the replica count consulted.
fn fleet_metrics_endpoint(state: &Arc<CoordState>, path: &str) -> Response {
    let prometheus = path
        .split_once('?')
        .map(|(_, q)| q.split('&').any(|kv| kv == "format=prometheus"))
        .unwrap_or(false);
    const KINDS: [&str; 4] = ["e2e", "queue_wait", "exec", "ttft"];
    struct ModelAgg {
        enqueued: i64,
        completed: i64,
        failed: i64,
        merged_batches: i64,
        queue_depth: i64,
        plan_hits: i64,
        plan_misses: i64,
        latency: Vec<crate::obs::HistSnapshot>,
    }
    let mut agg: BTreeMap<String, ModelAgg> = BTreeMap::new();
    let mut consulted = 0usize;
    for rep in state.core.registry.snapshot() {
        if rep.health == Health::Dead {
            continue;
        }
        let Ok((200, body)) = http::get_timeout(rep.addr, "/v1/metrics", state.core.io_timeout)
        else {
            continue;
        };
        let Ok(s) = std::str::from_utf8(&body) else { continue };
        let Ok(j) = parse(s) else { continue };
        let Some(models) = j.as_object() else { continue };
        consulted += 1;
        for (name, m) in models {
            if name.starts_with('_') {
                continue;
            }
            let e = agg.entry(name.clone()).or_insert_with(|| ModelAgg {
                enqueued: 0,
                completed: 0,
                failed: 0,
                merged_batches: 0,
                queue_depth: 0,
                plan_hits: 0,
                plan_misses: 0,
                latency: vec![crate::obs::HistSnapshot::default(); KINDS.len()],
            });
            e.enqueued += m.get("enqueued").as_i64().unwrap_or(0);
            e.completed += m.get("completed").as_i64().unwrap_or(0);
            e.failed += m.get("failed").as_i64().unwrap_or(0);
            e.merged_batches += m.get("merged_batches").as_i64().unwrap_or(0);
            e.queue_depth += m.get("queue_depth").as_i64().unwrap_or(0);
            // AOT plan-cache admission outcomes (absent pre-plan replicas
            // contribute zero)
            e.plan_hits += m.get("plan").get("hits").as_i64().unwrap_or(0);
            e.plan_misses += m.get("plan").get("misses").as_i64().unwrap_or(0);
            for (slot, kind) in e.latency.iter_mut().zip(KINDS.iter()) {
                if let Some(h) = crate::obs::HistSnapshot::from_json(m.get("latency").get(kind)) {
                    slot.merge(&h);
                }
            }
        }
    }
    if prometheus {
        let mut text = String::new();
        text.push_str("# TYPE nnscope_latency_seconds histogram\n");
        for (name, a) in &agg {
            for (kind, h) in KINDS.iter().zip(a.latency.iter()) {
                crate::obs::registry::prometheus_histogram(&mut text, name, kind, h);
            }
        }
        text.push_str("# TYPE nnscope_fleet_replicas gauge\n");
        text.push_str(&format!("nnscope_fleet_replicas {consulted}\n"));
        return Response::bytes(200, "text/plain; version=0.0.4", text.into_bytes());
    }
    let mut out = BTreeMap::new();
    for (name, a) in agg {
        out.insert(
            name,
            Json::obj(vec![
                ("enqueued", Json::from(a.enqueued)),
                ("completed", Json::from(a.completed)),
                ("failed", Json::from(a.failed)),
                ("merged_batches", Json::from(a.merged_batches)),
                ("queue_depth", Json::from(a.queue_depth)),
                (
                    "plan",
                    Json::obj(vec![
                        ("hits", Json::from(a.plan_hits)),
                        ("misses", Json::from(a.plan_misses)),
                    ]),
                ),
                (
                    "latency",
                    Json::obj(
                        KINDS
                            .iter()
                            .zip(a.latency.iter())
                            .map(|(&k, h)| (k, h.to_json()))
                            .collect(),
                    ),
                ),
            ]),
        );
    }
    out.insert(
        "_fleet".to_string(),
        Json::obj(vec![
            ("replicas", Json::from(consulted as i64)),
            ("policy", Json::from(state.core.router.policy.as_str())),
        ]),
    );
    Response::json(200, Json::Object(out).to_string())
}

/// `GET /v1/fleet/hotops`: the fleet's hottest ops by cumulative profiled
/// self-time. Fans out to every non-dead replica's `/v1/debug/hotops`
/// (each replica's table covers all profiled requests since its boot) and
/// merges per-op `(count, self_ns)` pairs by addition — legal for the
/// same reason histogram merging is: op kinds are a fleet-wide closed
/// set, so summed self-times equal the self-times of the concatenated
/// profiles. The answer to "what is the fleet spending its cycles on?"
/// without downloading any individual profile.
fn fleet_hotops_endpoint(state: &Arc<CoordState>) -> Response {
    let mut acc: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    let mut consulted = 0usize;
    for rep in state.core.registry.snapshot() {
        if rep.health == Health::Dead {
            continue;
        }
        let Ok((200, body)) =
            http::get_timeout(rep.addr, "/v1/debug/hotops", state.core.io_timeout)
        else {
            continue;
        };
        let Ok(s) = std::str::from_utf8(&body) else { continue };
        let Ok(j) = parse(s) else { continue };
        consulted += 1;
        crate::obs::profile::merge_hotops(&mut acc, &j);
    }
    let mut j = crate::obs::profile::hotops_json(&acc, 64);
    j.set("replicas", Json::from(consulted as i64));
    Response::json(200, j.to_string())
}

/// `GET /v1/debug/requests`: the coordinator's bounded ring of recently
/// routed requests (trace id, model, attempts, outcome), oldest first.
fn debug_requests_endpoint(state: &Arc<CoordState>) -> Response {
    Response::json(
        200,
        Json::obj(vec![("requests", Json::Array(state.ring.snapshot()))]).to_string(),
    )
}

/// Union of model manifests across live replicas, deduplicated by name —
/// the fleet looks like one big server to `NdifClient::models`. Replicas
/// are consulted healthiest-first with bounded per-call waits, and the
/// fan-out stops as soon as every registry-known model is covered, so one
/// slow replica doesn't tax a metadata call it adds nothing to.
fn models_endpoint(state: &Arc<CoordState>) -> Response {
    let want = state.core.registry.models();
    let mut replicas = state.core.registry.snapshot();
    replicas.sort_by(|a, b| a.health.cmp(&b.health).then_with(|| a.id.cmp(&b.id)));
    let mut by_name: BTreeMap<String, Json> = BTreeMap::new();
    for rep in replicas {
        if rep.health == Health::Dead {
            continue;
        }
        if !want.is_empty() && want.iter().all(|m| by_name.contains_key(m)) {
            break;
        }
        let Ok((200, body)) = http::get_timeout(rep.addr, "/v1/models", state.core.io_timeout)
        else {
            continue;
        };
        let Ok(s) = String::from_utf8(body) else { continue };
        let Ok(j) = parse(&s) else { continue };
        for m in j.get("models").as_array().unwrap_or(&[]) {
            if let Some(name) = m.get("name").as_str() {
                by_name.entry(name.to_string()).or_insert_with(|| m.clone());
            }
        }
    }
    Response::json(
        200,
        Json::obj(vec![("models", Json::Array(by_name.into_values().collect()))]).to_string(),
    )
}

fn trace_endpoint(state: &Arc<CoordState>, req: &Request) -> Response {
    let body = match body_json(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(model) = body.get("model").as_str().map(String::from) else {
        return Response::bad_request("graph missing model");
    };
    if state.core.registry.candidates(&model).is_empty() {
        return Response::json(
            404,
            format!("{{\"error\":\"model '{model}' not hosted by any live replica\"}}"),
        );
    }
    let id = format!("c-{}", state.next_id.fetch_add(1, Ordering::Relaxed));
    state.store.put_pending(&id);
    // retain the raw body: it is resubmitted verbatim on failover
    let payload = match req.body_str() {
        Ok(s) => s.to_string(),
        Err(e) => return Response::bad_request(&e.to_string()),
    };
    let auth = req.header("x-ndif-auth").map(String::from);
    // the trace id rides the whole routing pipeline: reuse the client's
    // (header) or mint here, send the SAME id to every replica attempt —
    // a failover retry is a new attempt of one request, not a new request
    let tid = req
        .header(crate::obs::TRACE_HEADER)
        .map(str::to_string)
        .unwrap_or_else(crate::obs::mint_trace_id);
    // bounded routing pool: jobs capture the core + store (never the pool
    // itself), so the queue gives backpressure without thread growth
    let core = Arc::clone(&state.core);
    let store = Arc::clone(&state.store);
    let st = Arc::clone(state);
    let rid = id.clone();
    state.routing.execute(move || {
        let t0 = Instant::now();
        let (res, attempts) = route_and_execute(&core, &model, &payload, auth.as_deref(), &tid);
        let total_us = t0.elapsed().as_micros() as i64;
        let ok = res.is_ok();
        match res {
            Ok(json) => {
                store.put_ready(&rid, annotate_timing(json, &tid, attempts, total_us));
            }
            Err(e) => store.put_failed(&rid, &e),
        }
        st.ring.push(Json::obj(vec![
            ("trace", Json::from(tid.as_str())),
            ("endpoint", Json::from("trace")),
            ("model", Json::from(model.as_str())),
            ("attempts", Json::from(attempts as i64)),
            ("total_us", Json::from(total_us)),
            ("ok", Json::Bool(ok)),
        ]));
    });
    Response::json(202, Json::obj(vec![("id", Json::from(id))]).to_string())
}

/// Stamp coordinator-side routing facts into a routed result's `"timing"`
/// metadata: the trace id (for results produced by an un-instrumented
/// replica), how many replica attempts the request took, and the
/// coordinator-observed total. Non-object bodies pass through untouched.
fn annotate_timing(body: String, tid: &str, attempts: usize, total_us: i64) -> String {
    let Ok(mut j) = parse(&body) else { return body };
    if j.as_object().is_none() {
        return body;
    }
    let mut timing = match j.get("timing") {
        Json::Object(o) => Json::Object(o.clone()),
        _ => Json::obj(vec![("trace", Json::from(tid))]),
    };
    timing.set("attempts", Json::from(attempts as i64));
    timing.set("coordinator_us", Json::from(total_us));
    j.set("timing", timing);
    j.to_string()
}

/// Outcome of one proxied attempt that *reached* a replica.
enum Routed {
    /// Result body ready to relay.
    Done(String),
    /// The replica answered but refused or failed the request itself
    /// (auth, validation, execution error) — not a replica fault, so the
    /// error is relayed to the client instead of failing over.
    Reject(u16, String),
}

/// Route one trace, failing over across replicas. Returns the outcome
/// plus how many replica attempts were made — every attempt carries the
/// SAME trace id in the `x-nnscope-trace` header, so the surviving
/// replica's `"timing"` metadata names the id the client started with.
fn route_and_execute(
    core: &RoutingCore,
    model: &str,
    payload: &str,
    auth: Option<&str>,
    trace_id: &str,
) -> (Result<String, String>, usize) {
    let mut tried: Vec<String> = Vec::new();
    let mut last_err = String::from("no candidate replicas");
    for attempt in 0..=core.max_retries {
        let candidates = core.registry.candidates(model);
        let Some(rep) = core.router.pick(&candidates, &tried) else {
            return (
                Err(format!(
                    "no live replica for model '{model}' after {attempt} attempt(s): {last_err}"
                )),
                attempt,
            );
        };
        core.registry.record_dispatch(&rep.id);
        // chaos hook: a simulated transport fault on this dispatch — the
        // attempt fails exactly like an unreachable replica, exercising
        // the failover path deterministically
        if let Some(FailAction::Error(msg)) = failpoint::hit("coord.dispatch") {
            core.registry.record_failure(&rep.id);
            tried.push(rep.id.clone());
            last_err = format!("injected dispatch fault: {msg}");
            continue;
        }
        match proxy_trace(core, &rep, payload, auth, trace_id) {
            Ok(Routed::Done(body)) => {
                core.registry.record_success(&rep.id);
                return (Ok(body), attempt + 1);
            }
            Ok(Routed::Reject(status, body)) => {
                core.registry.record_success(&rep.id);
                return (
                    Err(format!("replica {} rejected request ({status}): {body}", rep.id)),
                    attempt + 1,
                );
            }
            Err(e) => {
                core.registry.record_failure(&rep.id);
                tried.push(rep.id.clone());
                last_err = e;
            }
        }
    }
    (
        Err(format!(
            "request failed after {} attempt(s): {last_err}",
            core.max_retries + 1
        )),
        core.max_retries + 1,
    )
}

/// One attempt: submit the trace to `rep` and long-poll its result, every
/// exchange bounded by `io_timeout`. `Err` means the replica is
/// unreachable or lost state, and the caller fails the attempt over to
/// another replica. Failover is therefore **at-least-once**: if the
/// transport drops after the replica accepted the submit, the graph may
/// execute on two replicas (intervention results are pure reads, so the
/// duplicate is wasted compute, not corruption) and the first replica's
/// unfetched result stays parked in its store until restart.
fn proxy_trace(
    core: &RoutingCore,
    rep: &Replica,
    payload: &str,
    auth: Option<&str>,
    trace_id: &str,
) -> Result<Routed, String> {
    let mut headers = vec![
        ("Content-Type", "application/json"),
        (crate::obs::TRACE_HEADER, trace_id),
    ];
    if let Some(t) = auth {
        headers.push(("x-ndif-auth", t));
    }
    let (status, body) = http::http_request_timeout(
        rep.addr,
        "POST",
        "/v1/trace",
        payload.as_bytes(),
        &headers,
        core.io_timeout,
    )
    .map_err(|e| e.to_string())?;
    let body_s = String::from_utf8_lossy(&body).into_owned();
    if status == 503 {
        return Err(format!("replica overloaded: {body_s}"));
    }
    if status != 202 {
        return Ok(Routed::Reject(status, body_s));
    }
    let remote_id = parse(&body_s)
        .ok()
        .and_then(|j| j.get("id").as_str().map(String::from))
        .ok_or_else(|| "submit response missing id".to_string())?;
    // ask the replica to hold each poll for half the socket timeout so a
    // legitimate long-poll never trips the read deadline (the floor is 1ms,
    // not a fixed value, so tiny io_timeouts still satisfy hold < read)
    let hold_ms = (core.io_timeout.as_millis() as u64 / 2).clamp(1, 5_000);
    let deadline = Instant::now() + core.request_timeout;
    loop {
        if Instant::now() >= deadline {
            return Err(format!("replica {} result timed out", rep.id));
        }
        let (status, body) = http::get_timeout(
            rep.addr,
            &format!("/v1/result/{remote_id}?timeout_ms={hold_ms}"),
            core.io_timeout,
        )
        .map_err(|e| e.to_string())?;
        match status {
            200 => return Ok(Routed::Done(String::from_utf8_lossy(&body).into_owned())),
            202 => continue,
            500 => return Ok(Routed::Reject(500, String::from_utf8_lossy(&body).into_owned())),
            404 => return Err(format!("replica {} lost result {remote_id}", rep.id)),
            other => return Err(format!("replica {} result status {other}", rep.id)),
        }
    }
}

/// Proxy a streaming-generation request (`POST /v1/stream`) to a replica,
/// relaying event lines as they arrive — the coordinator is transparent:
/// clients see the same chunked NDJSON surface a single server exposes.
///
/// Failover semantics differ by phase:
/// * **before the stream opens** (connect failure, 503, non-200): retry
///   another candidate, bounded by `max_retries` — no client-visible state
///   exists yet;
/// * **mid-stream** (the replica dies after events were relayed): the
///   coordinator does NOT silently re-run the request on another replica
///   (the client already consumed a prefix; replaying would duplicate
///   steps). It appends a terminal
///   `{"event":"error", "error":…, "retryable":true}` tail event and ends
///   the stream cleanly, mirroring the session-failover contract — the
///   client restarts the stream if it wants the rest.
fn stream_endpoint(state: &Arc<CoordState>, req: &Request) -> Response {
    let body = match body_json(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(model) = body.get("model").as_str().map(String::from) else {
        return Response::bad_request("graph missing model");
    };
    let payload = match req.body_str() {
        Ok(s) => s.to_string(),
        Err(e) => return Response::bad_request(&e.to_string()),
    };
    let tid = req
        .header(crate::obs::TRACE_HEADER)
        .map(str::to_string)
        .unwrap_or_else(crate::obs::mint_trace_id);
    let mut headers = vec![
        ("Content-Type", "application/json"),
        (crate::obs::TRACE_HEADER, tid.as_str()),
    ];
    let auth = req.header("x-ndif-auth").map(String::from);
    if let Some(t) = &auth {
        headers.push(("x-ndif-auth", t.as_str()));
    }

    let mut tried: Vec<String> = Vec::new();
    let mut last_err = String::from("no candidate replicas");
    for _ in 0..=state.core.max_retries {
        let candidates = state.core.registry.candidates(&model);
        let Some(rep) = state.core.router.pick(&candidates, &tried) else { break };
        state.core.registry.record_dispatch(&rep.id);
        // connect is bounded tight so a dead replica fails over fast; the
        // read deadline is per-chunk and generous — streams legitimately
        // pause between decode steps while the model computes
        match http::http_request_stream(
            rep.addr,
            "POST",
            "/v1/stream",
            payload.as_bytes(),
            &headers,
            state.core.io_timeout,
            state.core.request_timeout,
        ) {
            Ok((200, reader)) => {
                return relay_stream(Arc::clone(&state.core), rep.id.clone(), reader);
            }
            Ok((503, mut reader)) => {
                state.core.registry.record_failure(&rep.id);
                tried.push(rep.id.clone());
                let b = reader.read_body().unwrap_or_default();
                last_err = format!("replica busy (503): {}", String::from_utf8_lossy(&b));
            }
            Ok((status, mut reader)) => {
                // the replica refused the request itself (auth, validation):
                // relay its verdict — not a replica fault
                state.core.registry.record_success(&rep.id);
                let b = reader.read_body().unwrap_or_default();
                return Response::json(status, String::from_utf8_lossy(&b).into_owned());
            }
            Err(e) => {
                state.core.registry.record_failure(&rep.id);
                tried.push(rep.id.clone());
                last_err = e.to_string();
            }
        }
    }
    Response::json(
        503,
        format!(
            "{{\"error\":{}}}",
            Json::from(format!("no live replica for stream: {last_err}"))
        ),
    )
}

/// Relay one replica's open event stream to the client, converting a
/// mid-stream transport death into the retryable tail event.
fn relay_stream(
    core: Arc<RoutingCore>,
    replica_id: String,
    mut reader: http::HttpStream,
) -> Response {
    let mut finished = false;
    Response::chunked(
        200,
        "application/x-ndjson",
        Box::new(move || {
            if finished {
                return Chunk::End;
            }
            match reader.next_line() {
                Ok(Some(mut line)) => {
                    line.push('\n');
                    Chunk::Data(line.into_bytes())
                }
                Ok(None) => {
                    // clean chunked terminator from the replica
                    core.registry.record_success(&replica_id);
                    finished = true;
                    Chunk::End
                }
                Err(e) => {
                    // the replica died (or hung past the read deadline)
                    // mid-stream: no silent truncation, no replay — a
                    // retryable tail event, then a clean end
                    core.registry.record_failure(&replica_id);
                    finished = true;
                    let tail = Json::obj(vec![
                        ("event", Json::from("error")),
                        (
                            "error",
                            Json::from(format!(
                                "replica {replica_id} died mid-stream ({e}); restart the stream"
                            )),
                        ),
                        ("retryable", Json::Bool(true)),
                    ])
                    .to_string();
                    Chunk::Data(format!("{tail}\n").into_bytes())
                }
            }
        }),
    )
}

/// `503 {"error": …, "retryable": true}` — the session's server-side state
/// is gone (replica death / transport failure); the client should restart
/// the session rather than expect its parameters to still exist.
fn retryable_503(msg: String) -> Response {
    Response::json(
        503,
        Json::obj(vec![("error", Json::from(msg)), ("retryable", Json::Bool(true))]).to_string(),
    )
}

/// Sessions are routed whole: all traces of a session go to one replica so
/// FIFO ordering is preserved (§B.1); the response is relayed verbatim.
/// A named (persistent) session is sticky — see the module docs.
fn session_endpoint(state: &Arc<CoordState>, req: &Request) -> Response {
    let body = match body_json(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(traces) = body.get("traces").as_array() else {
        return Response::bad_request("session missing traces");
    };
    let sticky = body.get("session").as_str().map(String::from);
    let mut models: Vec<String> = Vec::new();
    for t in traces {
        if let Some(m) = t.get("model").as_str() {
            if !models.iter().any(|x| x == m) {
                models.push(m.to_string());
            }
        }
    }
    let Some(first) = models.first().cloned() else {
        return Response::bad_request("session traces missing model");
    };
    let payload = match req.body_str() {
        Ok(s) => s.to_string(),
        Err(e) => return Response::bad_request(&e.to_string()),
    };
    let tid = req
        .header(crate::obs::TRACE_HEADER)
        .map(str::to_string)
        .unwrap_or_else(crate::obs::mint_trace_id);
    let mut headers = vec![
        ("Content-Type", "application/json"),
        (crate::obs::TRACE_HEADER, tid.as_str()),
    ];
    let auth = req.header("x-ndif-auth").map(String::from);
    if let Some(t) = &auth {
        headers.push(("x-ndif-auth", t.as_str()));
    }

    // a pinned session has exactly one legal destination: the replica
    // holding its state — never fail over, surface state loss instead
    if let Some(sid) = &sticky {
        loop {
            let pinned = state.pinned_replica(sid);
            let (rep, fresh) = if let Some(rid) = pinned {
                let rep = state
                    .core
                    .registry
                    .snapshot()
                    .into_iter()
                    .find(|r| r.id == rid && r.health != Health::Dead);
                let Some(rep) = rep else {
                    state.sessions.lock().unwrap().remove(sid);
                    return retryable_503(format!(
                        "session '{sid}' state lost: replica {rid} is dead; restart the session"
                    ));
                };
                (rep, false)
            } else {
                // fresh placement: pick a candidate, then claim the pin
                // atomically — losing the claim race means a concurrent
                // request already placed this session, so loop and honor
                // the winner's pin instead of forking state
                let candidates: Vec<Replica> = state
                    .core
                    .registry
                    .candidates(&first)
                    .into_iter()
                    .filter(|r| models.iter().all(|m| r.models.iter().any(|x| x == m)))
                    .collect();
                let Some(rep) = state.core.router.pick(&candidates, &[]) else {
                    return Response::json(
                        503,
                        format!(
                            "{{\"error\":{}}}",
                            Json::from(format!("no live replica for session '{sid}'"))
                        ),
                    );
                };
                let claimed = {
                    let mut m = state.sessions.lock().unwrap();
                    match m.entry(sid.clone()) {
                        std::collections::hash_map::Entry::Vacant(v) => {
                            v.insert(Pin { replica: rep.id.clone(), at: Instant::now() });
                            true
                        }
                        std::collections::hash_map::Entry::Occupied(_) => false,
                    }
                };
                if !claimed {
                    continue;
                }
                (rep, true)
            };
            state.core.registry.record_dispatch(&rep.id);
            match http::http_request_deadlines(
                rep.addr,
                "POST",
                "/v1/session",
                payload.as_bytes(),
                &headers,
                state.core.io_timeout,
                state.core.request_timeout,
            ) {
                // relay whatever the state-holding replica says — even its
                // errors belong to this session, not to another replica
                Ok((status, b)) => {
                    state.core.registry.record_success(&rep.id);
                    let mut m = state.sessions.lock().unwrap();
                    if fresh && (400..500).contains(&status) {
                        // refused at submit: no state was created, release
                        // the freshly claimed pin
                        m.remove(sid);
                    } else if let Some(p) = m.get_mut(sid) {
                        p.at = Instant::now();
                    }
                    drop(m);
                    return Response::json(status, String::from_utf8_lossy(&b).into_owned());
                }
                Err(e) => {
                    // the replica may be alive (slow) with the state intact
                    // — keep the pin so a retried/restarted session still
                    // targets it; a genuinely dead replica is unpinned once
                    // the registry marks it Dead
                    state.core.registry.record_failure(&rep.id);
                    return retryable_503(format!(
                        "session '{sid}' request failed in transit ({e}); restart the session"
                    ));
                }
            }
        }
    }

    let mut tried: Vec<String> = Vec::new();
    let mut last_err = String::from("no candidate replicas");
    for _ in 0..=state.core.max_retries {
        // the chosen replica must host every model the session touches
        let candidates: Vec<Replica> = state
            .core
            .registry
            .candidates(&first)
            .into_iter()
            .filter(|r| models.iter().all(|m| r.models.iter().any(|x| x == m)))
            .collect();
        let Some(rep) = state.core.router.pick(&candidates, &tried) else { break };
        state.core.registry.record_dispatch(&rep.id);
        // connect is bounded tight so a dead replica fails over fast, but
        // the read waits out the full request timeout — sessions run
        // synchronously on the replica and legitimately hold the response
        match http::http_request_deadlines(
            rep.addr,
            "POST",
            "/v1/session",
            payload.as_bytes(),
            &headers,
            state.core.io_timeout,
            state.core.request_timeout,
        ) {
            // 503 = replica queue unavailable, same retryable class as a
            // transport failure on the trace path
            Ok((503, b)) => {
                state.core.registry.record_failure(&rep.id);
                tried.push(rep.id.clone());
                last_err = format!("replica busy (503): {}", String::from_utf8_lossy(&b));
            }
            Ok((status, b)) => {
                state.core.registry.record_success(&rep.id);
                return Response::json(status, String::from_utf8_lossy(&b).into_owned());
            }
            Err(e) => {
                state.core.registry.record_failure(&rep.id);
                tried.push(rep.id.clone());
                last_err = e.to_string();
            }
        }
    }
    Response::json(
        503,
        format!(
            "{{\"error\":{}}}",
            Json::from(format!("no live replica for session: {last_err}"))
        ),
    )
}

/// Proxy `GET`/`DELETE /v1/session/<id>` (with the client's auth header)
/// to the replica pinned for that session; `DELETE` also unpins it here.
fn session_proxy_endpoint(state: &Arc<CoordState>, req: &Request, method: &str) -> Response {
    let path = req.path.as_str();
    let sid = &path["/v1/session/".len()..];
    let Some(rid) = state.pinned_replica(sid) else {
        return Response::not_found();
    };
    let rep = state
        .core
        .registry
        .snapshot()
        .into_iter()
        .find(|r| r.id == rid && r.health != Health::Dead);
    let Some(rep) = rep else {
        // the state died with the replica: a DELETE has nothing left to
        // drop, a GET has nothing left to show
        state.sessions.lock().unwrap().remove(sid);
        return match method {
            "DELETE" => Response::json(200, "{\"dropped\":true}".into()),
            _ => Response::not_found(),
        };
    };
    let mut headers: Vec<(&str, &str)> = Vec::new();
    if let Some(t) = req.header("x-ndif-auth") {
        headers.push(("x-ndif-auth", t));
    }
    let out =
        http::http_request_timeout(rep.addr, method, path, b"", &headers, state.core.io_timeout);
    match out {
        Ok((status, b)) => {
            // unpin only when the replica confirmed the state is gone —
            // a rejected DELETE (401 unauthorized) must not let an
            // unauthenticated caller orphan someone else's pinned state
            if method == "DELETE" && (status == 200 || status == 404) {
                state.sessions.lock().unwrap().remove(sid);
            }
            Response::json(status, String::from_utf8_lossy(&b).into_owned())
        }
        // transient transport failure must NOT unpin a live session; a
        // dead replica is unpinned once the registry marks it Dead
        Err(e) => retryable_503(format!("session '{sid}' replica unreachable ({e})")),
    }
}

fn result_endpoint(state: &Arc<CoordState>, path: &str) -> Response {
    let (id, timeout_ms) = match parse_result_path(path) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    // wait_outcome evicts completed entries on pickup
    match state.store.wait_outcome(id, Duration::from_millis(timeout_ms)) {
        Some(Ok(json)) => Response::json(200, json),
        Some(Err(e)) => Response::json(500, format!("{{\"error\":{}}}", Json::from(e))),
        None => match state.store.peek(id) {
            Some(Entry::Pending) => Response::json(202, "{\"status\":\"pending\"}".into()),
            _ => Response::not_found(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_payload_sums_across_models() {
        let body = br#"{"a":{"queue_depth":2,"completed":5,"failed":1},
                        "b":{"queue_depth":3,"completed":7,"failed":0}}"#;
        assert_eq!(parse_metrics(body), (5, 12, 1));
        assert_eq!(parse_metrics(b"not json"), (0, 0, 0));
        assert_eq!(parse_metrics(b"[]"), (0, 0, 0));
    }

    #[test]
    fn config_default_is_sane() {
        let cfg = CoordinatorConfig::local();
        assert_eq!(cfg.policy, Policy::LeastLoaded);
        assert!(cfg.max_retries >= 1);
        assert!(cfg.replicas.is_empty());
    }
}
