//! L3 fleet coordination (§3.3, Fig. 4): many NDIF deployments behind one
//! routing front.
//!
//! A single [`crate::server::NdifServer`] is one *replica*: it preloads
//! models and serves intervention requests. The coordinator is the layer
//! the paper draws above the model services — the piece that lets "many
//! users share GPU resources across a fleet of preloaded model
//! deployments":
//!
//! * [`registry`] — which replicas exist, which models each serves, and
//!   how healthy each looks (heartbeat-derived Alive/Degraded/Dead);
//! * [`router`] — pluggable routing policies (round-robin, least-loaded
//!   on queue depth, latency-aware on advertised
//!   [`crate::netsim::NetSim`] link profiles);
//! * [`api`] — the coordinator HTTP front: it mirrors the single-server
//!   NDIF API so clients are fleet-agnostic, adds `/v1/fleet/*`
//!   management endpoints, and fails accepted requests over to surviving
//!   replicas when a deployment dies mid-request.
//!
//! Replicas join the fleet by setting
//! [`crate::server::NdifConfig::coordinator`]; they self-register on
//! startup and push heartbeats carrying
//! [`crate::scheduler::LoadSnapshot`]s. `nnscope coordinate` runs a
//! standalone coordinator.

pub mod api;
pub mod registry;
pub mod router;

pub use api::{Coordinator, CoordinatorConfig};
pub use registry::{Health, HealthPolicy, Registry, Replica};
pub use router::{Policy, Router};
