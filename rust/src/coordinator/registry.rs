//! Fleet deployment registry: which replicas serve which models, and how
//! healthy each one currently looks (L3 of the NDIF architecture, §3.3
//! Fig. 4).
//!
//! A *replica* is one whole [`crate::server::NdifServer`] deployment.
//! Replicas register over the HTTP substrate (`POST /v1/fleet/register`),
//! push periodic heartbeats carrying a load snapshot, and are additionally
//! probed by the coordinator's monitor thread. Health is always *derived*,
//! never stored authority:
//!
//! * [`Health::Alive`] — heartbeats fresh, no recent transport failures;
//! * [`Health::Degraded`] — heartbeats aging past `degraded_after`, or at
//!   least one recent routing/probe failure: still routable, but only when
//!   no fully-alive replica hosts the model;
//! * [`Health::Dead`] — heartbeats older than `dead_after` or
//!   `failure_limit` consecutive failures: never routed to; revived only by
//!   a fresh heartbeat or re-registration.

use std::collections::{BTreeMap, BTreeSet};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Replica health, derived from heartbeat age and observed failures.
/// Ordered best-first so routers can sort candidate lists.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Health {
    Alive,
    Degraded,
    Dead,
}

impl Health {
    pub fn as_str(self) -> &'static str {
        match self {
            Health::Alive => "alive",
            Health::Degraded => "degraded",
            Health::Dead => "dead",
        }
    }
}

/// One registered replica endpoint (snapshot; the registry owns the truth).
#[derive(Clone, Debug)]
pub struct Replica {
    pub id: String,
    pub addr: SocketAddr,
    /// Models this replica preloaded and serves.
    pub models: Vec<String>,
    pub health: Health,
    pub last_heartbeat: Instant,
    /// Queue depth reported by the replica's last heartbeat/probe.
    pub queue_depth: usize,
    /// Requests the coordinator dispatched here and has not yet seen finish
    /// (fresher than the heartbeat-reported queue depth).
    pub inflight: usize,
    pub completed: u64,
    pub failed: u64,
    /// Requests ever routed here by the coordinator.
    pub routed: u64,
    pub consecutive_failures: u32,
    /// One-way link latency (seconds) the replica advertises — its
    /// [`crate::netsim::NetSim`] profile — used by latency-aware routing.
    pub latency_s: f64,
    /// Observed end-to-end p95 latency (ms) from the replica's merged
    /// request histograms, carried on heartbeats/probes; `0.0` until the
    /// replica has served traffic. Routers use it as a tie-break so two
    /// equally-queued replicas split by who actually answers faster.
    pub p95_ms: f64,
}

impl Replica {
    /// Router cost proxy: work queued on the replica plus work dispatched
    /// by the coordinator that the replica has not yet reported back.
    pub fn load(&self) -> usize {
        self.queue_depth + self.inflight
    }
}

/// Thresholds turning heartbeat age / failure counts into [`Health`].
///
/// Failure counts are hysteretic: one dropped probe or one failed dispatch
/// is forgiven (`degraded_failures` consecutive misses demote to
/// Degraded, `failure_limit` to Dead) — a single packet-loss blip must not
/// drain a healthy replica's traffic, while a genuinely sick replica still
/// decays in a bounded number of probe intervals.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    pub degraded_after: Duration,
    pub dead_after: Duration,
    /// Consecutive transport failures before a replica is demoted to
    /// [`Health::Degraded`] (must be ≤ `failure_limit` to matter).
    pub degraded_failures: u32,
    /// Consecutive transport failures before a replica is declared dead.
    pub failure_limit: u32,
}

impl Default for HealthPolicy {
    fn default() -> HealthPolicy {
        HealthPolicy {
            degraded_after: Duration::from_secs(1),
            dead_after: Duration::from_secs(5),
            degraded_failures: 2,
            failure_limit: 3,
        }
    }
}

/// Thread-safe replica registry with heartbeat-derived health states.
pub struct Registry {
    replicas: Mutex<BTreeMap<String, Replica>>,
    next_id: AtomicU64,
    policy: HealthPolicy,
}

impl Registry {
    pub fn new(policy: HealthPolicy) -> Registry {
        Registry {
            replicas: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            policy,
        }
    }

    /// Register (or re-register) a replica. An explicit `id` is always
    /// honored — even when unknown — so a replica recovering from a
    /// coordinator restart (heartbeat answered 404) reclaims its identity
    /// instead of looping on a freshly minted one; without an id, an
    /// address match reclaims the existing entry, else a fresh `rep-N` is
    /// minted. Registration always resets the replica to [`Health::Alive`]
    /// with a fresh heartbeat.
    pub fn register(
        &self,
        addr: SocketAddr,
        models: Vec<String>,
        latency_s: f64,
        id: Option<&str>,
    ) -> String {
        let mut g = self.replicas.lock().unwrap();
        let id = match id {
            Some(i) if !i.is_empty() => {
                // keep the mint counter ahead of reclaimed ids so a later
                // fresh registration can never collide with this entry
                if let Some(n) = i.strip_prefix("rep-").and_then(|s| s.parse::<u64>().ok()) {
                    self.next_id.fetch_max(n + 1, Ordering::Relaxed);
                }
                // one entry per address: drop any stale entry another id
                // left behind for the same endpoint
                let stale: Vec<String> = g
                    .values()
                    .filter(|r| r.addr == addr && r.id != i)
                    .map(|r| r.id.clone())
                    .collect();
                for s in stale {
                    g.remove(&s);
                }
                i.to_string()
            }
            _ => g
                .values()
                .find(|r| r.addr == addr)
                .map(|r| r.id.clone())
                .unwrap_or_else(|| {
                    format!("rep-{}", self.next_id.fetch_add(1, Ordering::Relaxed))
                }),
        };
        let rep = g.entry(id.clone()).or_insert_with(|| Replica {
            id: id.clone(),
            addr,
            models: Vec::new(),
            health: Health::Alive,
            last_heartbeat: Instant::now(),
            queue_depth: 0,
            inflight: 0,
            completed: 0,
            failed: 0,
            routed: 0,
            consecutive_failures: 0,
            latency_s,
            p95_ms: 0.0,
        });
        rep.addr = addr;
        if !models.is_empty() {
            rep.models = models;
        }
        rep.latency_s = latency_s;
        rep.consecutive_failures = 0;
        rep.health = Health::Alive;
        rep.last_heartbeat = Instant::now();
        id
    }

    /// Remove a replica (graceful shutdown). Returns false on unknown id.
    pub fn deregister(&self, id: &str) -> bool {
        self.replicas.lock().unwrap().remove(id).is_some()
    }

    /// Record a heartbeat with the replica's load snapshot and observed
    /// p95 latency (ms; pass `0.0` when the replica reports none).
    /// Returns false on unknown id (the replica should re-register).
    pub fn heartbeat(
        &self,
        id: &str,
        queue_depth: usize,
        completed: u64,
        failed: u64,
        p95_ms: f64,
    ) -> bool {
        let mut g = self.replicas.lock().unwrap();
        match g.get_mut(id) {
            Some(rep) => {
                rep.queue_depth = queue_depth;
                rep.completed = completed;
                rep.failed = failed;
                // 0.0 means "no latency observed yet" — keep the last
                // real observation rather than zeroing the tie-break
                if p95_ms.is_finite() && p95_ms > 0.0 {
                    rep.p95_ms = p95_ms;
                }
                rep.consecutive_failures = 0;
                rep.last_heartbeat = Instant::now();
                rep.health = Health::Alive;
                true
            }
            None => false,
        }
    }

    /// Fill in the hosted-model list learned from a probe.
    pub fn set_models(&self, id: &str, models: Vec<String>) {
        if let Some(rep) = self.replicas.lock().unwrap().get_mut(id) {
            rep.models = models;
        }
    }

    /// The router dispatched a request to this replica.
    pub fn record_dispatch(&self, id: &str) {
        if let Some(rep) = self.replicas.lock().unwrap().get_mut(id) {
            rep.routed += 1;
            rep.inflight += 1;
        }
    }

    /// A dispatched request finished successfully on this replica.
    pub fn record_success(&self, id: &str) {
        if let Some(rep) = self.replicas.lock().unwrap().get_mut(id) {
            rep.inflight = rep.inflight.saturating_sub(1);
            rep.consecutive_failures = 0;
        }
    }

    /// A dispatched request failed at the transport level on this replica.
    pub fn record_failure(&self, id: &str) {
        if let Some(rep) = self.replicas.lock().unwrap().get_mut(id) {
            rep.inflight = rep.inflight.saturating_sub(1);
            rep.consecutive_failures += 1;
        }
    }

    /// An active probe (no dispatched request) failed to reach the replica.
    pub fn probe_failed(&self, id: &str) {
        if let Some(rep) = self.replicas.lock().unwrap().get_mut(id) {
            rep.consecutive_failures += 1;
        }
    }

    fn refresh(g: &mut BTreeMap<String, Replica>, policy: HealthPolicy) {
        let now = Instant::now();
        for rep in g.values_mut() {
            let age = now.saturating_duration_since(rep.last_heartbeat);
            rep.health = if rep.consecutive_failures >= policy.failure_limit
                || age > policy.dead_after
            {
                Health::Dead
            } else if rep.consecutive_failures >= policy.degraded_failures
                || age > policy.degraded_after
            {
                Health::Degraded
            } else {
                Health::Alive
            };
        }
    }

    /// Non-dead replicas hosting `model`, best health first (ties broken by
    /// id for determinism).
    pub fn candidates(&self, model: &str) -> Vec<Replica> {
        let mut g = self.replicas.lock().unwrap();
        Self::refresh(&mut g, self.policy);
        let mut v: Vec<Replica> = g
            .values()
            .filter(|r| r.health != Health::Dead && r.models.iter().any(|m| m == model))
            .cloned()
            .collect();
        v.sort_by(|a, b| a.health.cmp(&b.health).then_with(|| a.id.cmp(&b.id)));
        v
    }

    /// All replicas with refreshed health, id order.
    pub fn snapshot(&self) -> Vec<Replica> {
        let mut g = self.replicas.lock().unwrap();
        Self::refresh(&mut g, self.policy);
        g.values().cloned().collect()
    }

    /// Union of models hosted anywhere in the fleet.
    pub fn models(&self) -> BTreeSet<String> {
        self.replicas
            .lock()
            .unwrap()
            .values()
            .flat_map(|r| r.models.iter().cloned())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.replicas.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn fast_policy() -> HealthPolicy {
        HealthPolicy {
            degraded_after: Duration::from_millis(40),
            dead_after: Duration::from_millis(120),
            degraded_failures: 1,
            failure_limit: 2,
        }
    }

    /// Hysteretic policy: forgive one miss, degrade at two, kill at three.
    fn hysteresis_policy() -> HealthPolicy {
        HealthPolicy {
            degraded_after: Duration::from_secs(60),
            dead_after: Duration::from_secs(120),
            degraded_failures: 2,
            failure_limit: 3,
        }
    }

    #[test]
    fn register_heartbeat_and_candidates() {
        let reg = Registry::new(fast_policy());
        let id = reg.register(addr(7001), vec!["m".into()], 0.0, None);
        assert_eq!(reg.len(), 1);
        assert!(reg.heartbeat(&id, 3, 10, 1, 12.5));
        assert!(!reg.heartbeat("rep-999", 0, 0, 0, 0.0));
        let c = reg.candidates("m");
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].queue_depth, 3);
        assert_eq!(c[0].completed, 10);
        assert!((c[0].p95_ms - 12.5).abs() < 1e-12);
        // a heartbeat without latency data keeps the last observation
        assert!(reg.heartbeat(&id, 3, 10, 1, 0.0));
        assert!((reg.candidates("m")[0].p95_ms - 12.5).abs() < 1e-12);
        assert_eq!(c[0].health, Health::Alive);
        assert!(reg.candidates("other").is_empty());
        assert!(reg.models().contains("m"));
    }

    #[test]
    fn reregistration_keeps_identity() {
        let reg = Registry::new(fast_policy());
        let id1 = reg.register(addr(7002), vec!["m".into()], 0.0, None);
        // same address → same id
        let id2 = reg.register(addr(7002), vec![], 0.1, None);
        assert_eq!(id1, id2);
        assert_eq!(reg.len(), 1);
        let snap = reg.snapshot();
        // empty model list on re-register keeps the learned models
        assert_eq!(snap[0].models, vec!["m".to_string()]);
        assert!((snap[0].latency_s - 0.1).abs() < 1e-12);
        // explicit id → same entry even at a new address
        let id3 = reg.register(addr(7003), vec![], 0.0, Some(&id1));
        assert_eq!(id3, id1);
        assert_eq!(reg.snapshot()[0].addr, addr(7003));
    }

    #[test]
    fn unknown_explicit_id_is_reclaimed_after_restart() {
        // a replica re-registering with the id a previous coordinator
        // incarnation assigned must get that id back, not a fresh mint
        let reg = Registry::new(fast_policy());
        let id = reg.register(addr(7010), vec!["m".into()], 0.0, Some("rep-7"));
        assert_eq!(id, "rep-7");
        assert!(reg.heartbeat("rep-7", 0, 0, 0, 0.0), "heartbeats resolve after reclaim");
        // the mint counter moved past the reclaimed id: no collision
        let fresh = reg.register(addr(7011), vec!["m".into()], 0.0, None);
        assert_ne!(fresh, "rep-7");
        // reclaiming an id for an address a stale entry also claims
        // replaces the stale entry rather than duplicating the endpoint
        let dup = reg.register(addr(7011), vec![], 0.0, Some("rep-40"));
        assert_eq!(dup, "rep-40");
        let ids: Vec<String> = reg.snapshot().iter().map(|r| r.id.clone()).collect();
        assert!(!ids.contains(&fresh));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn health_decays_without_heartbeats() {
        let reg = Registry::new(fast_policy());
        let id = reg.register(addr(7004), vec!["m".into()], 0.0, None);
        assert_eq!(reg.snapshot()[0].health, Health::Alive);
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(reg.snapshot()[0].health, Health::Degraded);
        assert_eq!(reg.candidates("m").len(), 1, "degraded is still routable");
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(reg.snapshot()[0].health, Health::Dead);
        assert!(reg.candidates("m").is_empty(), "dead is not routable");
        // a fresh heartbeat revives it
        assert!(reg.heartbeat(&id, 0, 0, 0, 0.0));
        assert_eq!(reg.snapshot()[0].health, Health::Alive);
    }

    #[test]
    fn failures_kill_and_success_heals() {
        let reg = Registry::new(fast_policy());
        let id = reg.register(addr(7005), vec!["m".into()], 0.0, None);
        reg.record_dispatch(&id);
        reg.record_failure(&id);
        assert_eq!(reg.snapshot()[0].health, Health::Degraded);
        reg.probe_failed(&id);
        assert_eq!(reg.snapshot()[0].health, Health::Dead, "failure_limit=2 reached");
        // re-registration resurrects
        reg.register(addr(7005), vec![], 0.0, Some(&id));
        assert_eq!(reg.snapshot()[0].health, Health::Alive);
        reg.record_dispatch(&id);
        reg.record_success(&id);
        let snap = reg.snapshot();
        assert_eq!(snap[0].consecutive_failures, 0);
        assert_eq!(snap[0].inflight, 0);
        assert_eq!(snap[0].routed, 2);
    }

    #[test]
    fn hysteresis_transition_table() {
        // Full transition table under degraded_failures=2, failure_limit=3:
        // a single blip is forgiven; sustained misses decay in steps; any
        // heartbeat or dispatch success heals back to Alive.
        let reg = Registry::new(hysteresis_policy());
        let id = reg.register(addr(7007), vec!["m".into()], 0.0, None);
        assert_eq!(reg.snapshot()[0].health, Health::Alive, "fresh replica");

        reg.probe_failed(&id);
        assert_eq!(
            reg.snapshot()[0].health,
            Health::Alive,
            "one missed probe is forgiven (no flap on a single blip)"
        );

        reg.probe_failed(&id);
        assert_eq!(
            reg.snapshot()[0].health,
            Health::Degraded,
            "degraded_failures=2 consecutive misses demote"
        );
        assert_eq!(reg.candidates("m").len(), 1, "degraded still routable");

        reg.probe_failed(&id);
        assert_eq!(reg.snapshot()[0].health, Health::Dead, "failure_limit=3 kills");
        assert!(reg.candidates("m").is_empty());

        // A heartbeat resets the failure streak entirely.
        assert!(reg.heartbeat(&id, 0, 0, 0, 0.0));
        assert_eq!(reg.snapshot()[0].health, Health::Alive, "heartbeat heals");
        assert_eq!(reg.snapshot()[0].consecutive_failures, 0);

        // Dispatch failures follow the same ladder…
        reg.record_dispatch(&id);
        reg.record_failure(&id);
        assert_eq!(reg.snapshot()[0].health, Health::Alive);
        reg.record_dispatch(&id);
        reg.record_failure(&id);
        assert_eq!(reg.snapshot()[0].health, Health::Degraded);

        // …and one success (not just a heartbeat) also resets the streak.
        reg.record_dispatch(&id);
        reg.record_success(&id);
        assert_eq!(reg.snapshot()[0].health, Health::Alive, "success heals");
    }

    #[test]
    fn deregister_removes() {
        let reg = Registry::new(HealthPolicy::default());
        let id = reg.register(addr(7006), vec!["m".into()], 0.0, None);
        assert!(reg.deregister(&id));
        assert!(!reg.deregister(&id));
        assert!(reg.is_empty());
    }
}
