//! Streaming generation end-to-end: incremental event delivery, coordinator
//! transparency, mid-stream replica death (retryable tail, no hang, no
//! silent truncation), and request validation over the wire.

use std::time::{Duration, Instant};

use nnscope::client::remote::{is_retryable_stream_err, NdifClient, StreamEvent};
use nnscope::client::Trace;
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;

fn start_server() -> NdifServer {
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&["tiny-sim"]) };
    NdifServer::start(cfg).unwrap()
}

fn tokens() -> Tensor {
    Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect())
}

/// A probe trace: step-hook the mean of layer.0 (small per-step payload).
fn probe_trace() -> Trace {
    let mut tr = Trace::new("tiny-sim", &tokens());
    let h = tr.output("layer.0");
    let m = tr.mean(h);
    tr.step_hook(m);
    tr
}

/// A fat probe: step-hook the whole layer.0 hidden state, so events carry
/// kilobytes and a long stream cannot hide in socket buffers.
fn fat_trace() -> Trace {
    let mut tr = Trace::new("tiny-sim", &tokens());
    let h = tr.output("layer.0");
    tr.step_hook(h);
    tr
}

#[test]
fn stream_delivers_events_before_completion() {
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let steps = 6usize;

    let t0 = Instant::now();
    let mut first_event = None;
    let mut seen_steps = Vec::new();
    let mut done = None;
    for item in probe_trace().run_stream(&client, steps).unwrap() {
        match item.unwrap() {
            StreamEvent::Step { step, token, values, .. } => {
                if first_event.is_none() {
                    first_event = Some(t0.elapsed());
                }
                assert_eq!(step, seen_steps.len(), "events must arrive in step order");
                assert!(!values.values.is_empty(), "step event carries hooked values");
                seen_steps.push(token);
            }
            StreamEvent::Done { tokens, scores } => {
                assert_eq!(scores.len(), tokens.len());
                done = Some(tokens);
            }
        }
    }
    let total = t0.elapsed();
    let done = done.expect("stream must end with a done event");
    assert_eq!(seen_steps.len(), steps);
    assert_eq!(done, seen_steps, "done trajectory must match the streamed steps");
    assert!(
        first_event.expect("no step event") < total,
        "first event must land before the stream completes"
    );

    // the streamed trajectory matches plain (non-streaming) generation:
    // a pure probe must not perturb decoding
    let runner =
        nnscope::models::ModelRunner::load(&nnscope::models::artifacts_dir(), "tiny-sim").unwrap();
    let plain = runner.generate_plain(&tokens(), steps).unwrap();
    assert_eq!(done, plain.tokens);
}

#[test]
fn stream_rejections_are_clean_400s() {
    let server = start_server();
    let client = NdifClient::new(server.addr());

    // a step_hook graph on the one-shot trace endpoint points at /v1/stream
    let err = probe_trace().run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("/v1/stream"), "{err}");

    // grads are per-request, not per-step
    let mut tr = Trace::new("tiny-sim", &tokens());
    tr.targets(&[1.0]);
    let g = tr.grad("layer.0");
    tr.step_hook(g);
    let err = tr.run_stream(&client, 4).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");

    // batch > 1 is rejected at submit (streaming is single-sequence)
    let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[2, 16]));
    let h = tr.output("layer.0");
    tr.step_hook(h);
    let err = tr.run_stream(&client, 4).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("single-sequence"), "{err}");

    // a wrong-length prompt is rejected at submit too
    let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 8]));
    let h = tr.output("layer.0");
    tr.step_hook(h);
    let err = tr.run_stream(&client, 4).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("prompt"), "{err}");

    // steps are mandatory and bounded
    let (status, body) = nnscope::server::http::post(
        server.addr(),
        "/v1/stream",
        nnscope::graph::serde::to_json(probe_trace().graph()).to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    assert!(String::from_utf8_lossy(&body).contains("steps"));
}

#[test]
fn steering_setter_applies_at_every_step() {
    // an ablation setter changes the trajectory vs the plain stream —
    // per-step intervention execution, not just per-step observation
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let steps = 5usize;

    let collect = |tr: Trace| -> Vec<usize> {
        let mut out = Vec::new();
        for item in tr.run_stream(&client, steps).unwrap() {
            if let StreamEvent::Done { tokens, .. } = item.unwrap() {
                out = tokens;
            }
        }
        out
    };

    let plain = collect(probe_trace());
    let mut tr = Trace::new("tiny-sim", &tokens());
    let h = tr.output("layer.0");
    let z = tr.scale(h, 0.0);
    tr.set_output("layer.0", z);
    let l = tr.output("lm_head");
    let m = tr.mean(l);
    tr.step_hook(m);
    let steered = collect(tr);
    assert_eq!(plain.len(), steps);
    assert_eq!(steered.len(), steps);
    assert_ne!(plain, steered, "ablating layer.0 every step must change decoding");
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

fn coordinator() -> Coordinator {
    let mut cfg = CoordinatorConfig::local();
    cfg.policy = Policy::RoundRobin;
    cfg.probe_interval = Duration::from_millis(50);
    cfg.health.degraded_after = Duration::from_millis(400);
    cfg.health.dead_after = Duration::from_secs(2);
    Coordinator::start(cfg).unwrap()
}

fn replica(coord: &Coordinator) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.coordinator = Some(coord.addr().to_string());
    cfg.heartbeat = Duration::from_millis(50);
    NdifServer::start(cfg).unwrap()
}

#[test]
fn coordinator_proxies_streams_transparently() {
    let coord = coordinator();
    let _replica = replica(&coord);
    let client = NdifClient::new(coord.addr());
    let steps = 4usize;

    let mut events = 0usize;
    let mut done = false;
    for item in probe_trace().run_stream(&client, steps).unwrap() {
        match item.unwrap() {
            StreamEvent::Step { .. } => events += 1,
            StreamEvent::Done { tokens, .. } => {
                assert_eq!(tokens.len(), steps);
                done = true;
            }
        }
    }
    assert_eq!(events, steps);
    assert!(done, "proxied stream must terminate with done");
}

#[test]
fn killing_the_serving_replica_mid_stream_yields_retryable_tail() {
    let coord = coordinator();
    let rep = replica(&coord);
    let mut client = NdifClient::new(coord.addr());
    // bound every wait so a regression shows up as a test failure, not a
    // hang
    client.poll_timeout = Duration::from_secs(30);

    // fat events + a step count far beyond what socket buffers can absorb:
    // the decode is guaranteed to still be running when the replica dies
    let mut iter = fat_trace().run_stream(&client, 2000).unwrap();
    match iter.next().expect("stream opened").unwrap() {
        StreamEvent::Step { .. } => {}
        other => panic!("expected a step event first, got {other:?}"),
    }

    // kill from another thread: a real replica death is never synchronized
    // with the client's reads
    let killer = std::thread::spawn(move || {
        let mut rep = rep;
        rep.kill();
        rep
    });

    let deadline = Instant::now() + Duration::from_secs(60);
    let mut tail_err = None;
    for item in iter {
        assert!(
            Instant::now() < deadline,
            "no tail event within 60s of replica death (client would hang)"
        );
        match item {
            Ok(StreamEvent::Done { .. }) => {
                panic!("stream reported clean completion despite replica death")
            }
            Ok(StreamEvent::Step { .. }) => continue, // frames already in flight
            Err(e) => {
                tail_err = Some(e);
                break;
            }
        }
    }
    let e = tail_err.expect("stream ended with neither done nor an error item");
    assert!(is_retryable_stream_err(&e), "tail must be retryable: {e}");
    let _rep = killer.join().unwrap();

    // the fleet keeps serving: a fresh stream against a new replica works
    let _replacement = replica(&coord);
    let mut done = false;
    for item in probe_trace().run_stream(&client, 3).unwrap() {
        if let StreamEvent::Done { .. } = item.unwrap() {
            done = true;
        }
    }
    assert!(done, "fresh stream after failover must complete");
}

#[test]
fn direct_replica_death_surfaces_as_retryable_transport_error() {
    // no coordinator in between: the client itself sees the truncated
    // chunk stream and reports it retryably instead of hanging
    let server = start_server();
    let mut client = NdifClient::new(server.addr());
    client.poll_timeout = Duration::from_secs(30);

    let mut iter = fat_trace().run_stream(&client, 2000).unwrap();
    assert!(matches!(
        iter.next().expect("stream opened").unwrap(),
        StreamEvent::Step { .. }
    ));
    let killer = std::thread::spawn(move || {
        let mut server = server;
        server.kill();
        server
    });
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut tail_err = None;
    for item in iter {
        assert!(Instant::now() < deadline, "no error within 60s of server death");
        match item {
            Ok(StreamEvent::Done { .. }) => panic!("clean completion despite server death"),
            Ok(StreamEvent::Step { .. }) => continue,
            Err(e) => {
                tail_err = Some(e);
                break;
            }
        }
    }
    let e = tail_err.expect("no terminal item after server death");
    assert!(is_retryable_stream_err(&e), "{e}");
    let _server = killer.join().unwrap();
}
