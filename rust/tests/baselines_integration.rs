//! Cross-framework validation: every Table-1 mechanism and the Petals
//! swarm must produce identical activation-patching numerics — the
//! benchmarks then measure purely architectural costs.

use nnscope::baselines::hooks::{BaukitLike, NnsightLocal, PyveneLike};
use nnscope::baselines::tlens::TlensLike;
use nnscope::baselines::{patch_rows, Framework};
use nnscope::models::workload::IoiBatch;
use nnscope::models::{artifacts_dir, ModelRunner, ModelWeights};
use nnscope::netsim::{Mode, NetSim};
use nnscope::tensor::Tensor;

fn ioi() -> IoiBatch {
    let m = nnscope::runtime::Manifest::load(&artifacts_dir(), "tiny-sim").unwrap();
    IoiBatch::generate(2, m.vocab, m.seq, 7)
}

#[test]
fn all_frameworks_agree_on_patching_numerics() {
    // ensure weights.bin exists for the cold-load paths
    let m = nnscope::runtime::Manifest::load(&artifacts_dir(), "tiny-sim").unwrap();
    ModelWeights::ensure_on_disk(&m).unwrap();

    let batch = ioi();
    let baukit = BaukitLike::setup(&artifacts_dir(), "tiny-sim").unwrap();
    let pyvene = PyveneLike::setup(&artifacts_dir(), "tiny-sim").unwrap();
    let tlens = TlensLike::setup(&artifacts_dir(), "tiny-sim").unwrap();
    let nnsight = NnsightLocal::setup(&artifacts_dir(), "tiny-sim").unwrap();

    let a = baukit.activation_patch(&batch, 1).unwrap();
    let b = pyvene.activation_patch(&batch, 1).unwrap();
    let c = tlens.activation_patch(&batch, 1).unwrap();
    let d = nnsight.activation_patch(&batch, 1).unwrap();

    assert!(a.allclose(&b, 1e-6), "baukit vs pyvene: {}", a.max_abs_diff(&b));
    assert!(a.allclose(&c, 1e-6), "baukit vs tlens: {}", a.max_abs_diff(&c));
    assert!(a.allclose(&d, 1e-5), "baukit vs nnsight: {}", a.max_abs_diff(&d));
    // and the patch actually does something
    assert!(a.data().iter().any(|v| v.abs() > 1e-6));
}

#[test]
fn petals_standard_inference_matches_direct() {
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let swarm = nnscope::baselines::petals::PetalsSwarm::start(
        &artifacts_dir(),
        "tiny-sim",
        NetSim::new(0.0, 1e12, Mode::Account),
    )
    .unwrap();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
    let direct = runner.forward_plain(&tokens).unwrap();
    let petals = swarm.infer(&tokens).unwrap();
    assert!(
        direct.allclose(&petals, 1e-5),
        "diff {}",
        direct.max_abs_diff(&petals)
    );
    // two hidden-state transfers for plain inference
    let hb = runner.manifest.hidden_bytes(1) as u64;
    assert_eq!(swarm.link.bytes_transferred(), 2 * hb);
}

#[test]
fn petals_intervention_matches_hooked_run_and_costs_more_wire() {
    let swarm = nnscope::baselines::petals::PetalsSwarm::start(
        &artifacts_dir(),
        "tiny-sim",
        NetSim::new(0.0, 1e12, Mode::Account),
    )
    .unwrap();
    let batch = ioi();
    let tokens = batch.interleaved_tokens();
    let (padded, _) = swarm.runner().pad_tokens(&tokens).unwrap();
    let seq = swarm.runner().manifest.seq;

    swarm.link.reset();
    let petals_logits = swarm
        .patched_infer(&padded, 1, |t| patch_rows(t, seq))
        .unwrap();
    let hb = swarm.runner().manifest.hidden_bytes(padded.dims()[0]) as u64;
    // four hidden-state transfers for an intervention
    assert_eq!(swarm.link.bytes_transferred(), 4 * hb);

    // numerics equal the directly-hooked run
    let baukit = BaukitLike::setup(&artifacts_dir(), "tiny-sim").unwrap();
    let direct = baukit
        .run_with_hook(&padded, "layer.1", |t| patch_rows(t, seq))
        .unwrap();
    assert!(
        petals_logits.allclose(&direct, 1e-5),
        "diff {}",
        petals_logits.max_abs_diff(&direct)
    );
}

#[test]
fn tlens_standardization_is_real_work() {
    let tlens = TlensLike::setup(&artifacts_dir(), "tiny-sim").unwrap();
    assert_eq!(tlens.standardized.len(), tlens.runner().manifest.n_layers);
    let orig_wo = &tlens.runner().weights.modules["layer.0"][5];
    let std_wo = &tlens.standardized[0].wo_centered;
    assert_eq!(std_wo.dims(), &[orig_wo.dims()[1], orig_wo.dims()[0]]); // transposed
}

#[test]
fn pyvene_collect_scheme_returns_activations() {
    use nnscope::baselines::hooks::{InterventionConfig, InterventionType};
    let pv = PyveneLike::setup(&artifacts_dir(), "tiny-sim").unwrap();
    let tokens = Tensor::new(&[1, 16], vec![2.0; 16]);
    let scheme = [
        InterventionConfig { point: "layer.0".into(), kind: InterventionType::Collect },
        InterventionConfig {
            point: "layer.1".into(),
            kind: InterventionType::ZeroNeurons { from: 0, to: 4 },
        },
    ];
    let (logits, collected) = pv.run_scheme(&tokens, &scheme).unwrap();
    assert_eq!(collected.len(), 1);
    assert_eq!(collected[0].0, "layer.0");
    assert_eq!(collected[0].1.dims(), &[1, 16, 32]);
    assert_eq!(logits.dims(), &[1, 16, 64]);
}
