//! Observability integration: fleet percentile merging, trace-id
//! propagation across coordinator failover, and debug-ring bounding.
//!
//! The load-bearing assertion is bit-identity: a percentile served by the
//! coordinator's `/v1/fleet/metrics` (computed from per-bucket-merged
//! histograms) must equal — `f64::to_bits` equal, not approximately — the
//! percentile computed from the concatenation of the per-replica bucket
//! arrays. That is the property that makes fleet tail latency trustworthy:
//! merging is exact, not an average of averages.

use std::time::{Duration, Instant};

use nnscope::client::remote::NdifClient;
use nnscope::client::Trace;
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::json::{parse, Json};
use nnscope::obs::{percentile_from_counts, HistSnapshot, BUCKETS, TRACE_HEADER};
use nnscope::server::{http, NdifConfig, NdifServer};
use nnscope::tensor::Tensor;

fn coordinator(policy: Policy, probe: Duration) -> Coordinator {
    let mut cfg = CoordinatorConfig::local();
    cfg.policy = policy;
    cfg.probe_interval = probe;
    Coordinator::start(cfg).unwrap()
}

fn replica(coord: &Coordinator) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.coordinator = Some(coord.addr().to_string());
    cfg.heartbeat = Duration::from_millis(50);
    NdifServer::start(cfg).unwrap()
}

fn run_one(client: &NdifClient, v: f32) {
    let tokens = Tensor::new(&[1, 16], vec![v; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    tr.run_remote(client).unwrap();
}

fn get_json(addr: std::net::SocketAddr, path: &str) -> Json {
    let (status, body) = http::get(addr, path).unwrap();
    assert_eq!(status, 200, "{path}: {}", String::from_utf8_lossy(&body));
    parse(std::str::from_utf8(&body).unwrap()).unwrap()
}

/// Replica-side e2e snapshot once it has recorded `want` observations
/// (the worker records histograms just after publishing the result, so a
/// brief wait closes the race with the last client response).
fn e2e_when_counted(addr: std::net::SocketAddr, want: u64) -> HistSnapshot {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = get_json(addr, "/v1/metrics");
        if let Some(h) = HistSnapshot::from_json(j.get("tiny-sim").get("latency").get("e2e")) {
            if h.count >= want {
                return h;
            }
        }
        assert!(Instant::now() < deadline, "replica at {addr} never recorded {want} e2e obs");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn fleet_percentiles_match_concatenated_buckets() {
    let coord = coordinator(Policy::RoundRobin, Duration::from_millis(50));
    let r1 = replica(&coord);
    let r2 = replica(&coord);
    let client = NdifClient::new(coord.addr());
    let n = 6u64;
    for i in 0..n {
        run_one(&client, i as f32);
    }

    // quiesce: both replicas must have banked every observation before the
    // fleet endpoint fans out, and round-robin guarantees both saw traffic
    let (_, c1, _, _) = r1.metrics("tiny-sim").unwrap();
    let (_, c2, _, _) = r2.metrics("tiny-sim").unwrap();
    assert_eq!(c1 + c2, n);
    assert!(c1 >= 1 && c2 >= 1, "round-robin did not spread: {c1}/{c2}");
    let h1 = e2e_when_counted(r1.addr(), c1);
    let h2 = e2e_when_counted(r2.addr(), c2);

    let fleet = get_json(coord.addr(), "/v1/fleet/metrics");
    let m = fleet.get("tiny-sim");
    assert_eq!(m.get("completed").as_i64(), Some(n as i64));
    assert_eq!(fleet.get("_fleet").get("replicas").as_i64(), Some(2));
    let merged = HistSnapshot::from_json(m.get("latency").get("e2e")).unwrap();

    // "concatenating" the per-replica observations is exactly an
    // element-wise sum of their bucket arrays (boundaries are static)
    let mut concat = [0u64; BUCKETS];
    for (slot, (a, b)) in concat.iter_mut().zip(h1.counts.iter().zip(h2.counts.iter())) {
        *slot = a + b;
    }
    assert_eq!(merged.counts, concat, "fleet merge must be the per-bucket sum");
    assert_eq!(merged.count, n);
    for q in [0.5, 0.9, 0.95, 0.99] {
        assert_eq!(
            merged.percentile(q).to_bits(),
            percentile_from_counts(&concat, q).to_bits(),
            "fleet p{} must be bit-identical to the concatenated percentile",
            (q * 100.0) as u32
        );
    }

    // queue-wait and exec histograms merge through the same machinery
    for kind in ["queue_wait", "exec"] {
        let h = HistSnapshot::from_json(m.get("latency").get(kind)).unwrap();
        assert_eq!(h.count, n, "{kind} lost observations in the merge");
    }
}

#[test]
fn trace_id_survives_failover_retry() {
    // slow probe: the monitor must not notice the ghost before the request
    let coord = coordinator(Policy::LeastLoaded, Duration::from_secs(60));
    // a dead replica registered FIRST — least-loaded breaks the 0-load tie
    // by id, so the first routing attempt goes here and fails at transport
    let (status, _) = http::post(
        coord.addr(),
        "/v1/fleet/register",
        br#"{"addr":"127.0.0.1:9","models":["tiny-sim"],"latency_s":0.0}"#,
    )
    .unwrap();
    assert_eq!(status, 200);
    let real = replica(&coord);

    let tokens = Tensor::new(&[1, 16], vec![1.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let payload = nnscope::graph::serde::to_json(tr.graph()).to_string();

    let tid = "deadbeefcafef00d";
    let (status, body) = http::http_request(
        coord.addr(),
        "POST",
        "/v1/trace",
        payload.as_bytes(),
        &[("Content-Type", "application/json"), (TRACE_HEADER, tid)],
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();

    let (status, body) =
        http::get(coord.addr(), &format!("/v1/result/{id}?timeout_ms=30000")).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let timing = j.get("timing");
    // the surviving replica stamped its spans under the id the client
    // minted — the failover retry did NOT re-mint
    assert_eq!(timing.get("trace").as_str(), Some(tid));
    assert_eq!(timing.get("attempts").as_i64(), Some(2), "timing: {timing}");
    assert!(timing.get("coordinator_us").as_i64().unwrap_or(-1) >= 0);
    assert!(
        timing.get("spans").as_array().is_some_and(|s| !s.is_empty()),
        "replica spans missing: {timing}"
    );

    // the coordinator's own debug ring remembers the request by that id
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = get_json(coord.addr(), "/v1/debug/requests");
        let reqs = j.get("requests").as_array().unwrap().to_vec();
        if reqs
            .iter()
            .any(|r| r.get("trace").as_str() == Some(tid) && r.get("attempts").as_i64() == Some(2))
        {
            break;
        }
        assert!(Instant::now() < deadline, "coordinator ring never saw {tid}: {j}");
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(real);
}

#[test]
fn debug_ring_is_bounded() {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.trace_ring = 3;
    let server = NdifServer::start(cfg).unwrap();
    let client = NdifClient::new(server.addr());
    let n = 9;
    for i in 0..n {
        run_one(&client, i as f32);
    }

    // the ring fills to its bound and stays there; the worker pushes just
    // after the result publishes, so wait for the final push
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = get_json(server.addr(), "/v1/debug/requests");
        let reqs = j.get("requests").as_array().unwrap().to_vec();
        assert!(reqs.len() <= 3, "ring exceeded its bound: {} entries", reqs.len());
        if reqs.len() == 3 {
            for r in &reqs {
                assert_eq!(r.get("endpoint").as_str(), Some("trace"));
                assert!(r.get("trace").as_str().is_some_and(|t| !t.is_empty()));
            }
            break;
        }
        assert!(Instant::now() < deadline, "ring never filled: {j}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // client-visible timing: the observed variant surfaces the same spans
    let tokens = Tensor::new(&[1, 16], vec![3.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let out = client
        .run(tr.graph(), nnscope::client::ExecuteOptions::new().detailed())
        .unwrap();
    let timing = out.timing.expect("obs-enabled server must return timing metadata");
    assert!(timing.get("spans").as_array().is_some_and(|s| !s.is_empty()));
}

// ---------------------------------------------------------------------------
// Deep execution profiler
// ---------------------------------------------------------------------------

fn lens_trace(v: f32) -> Trace {
    let tokens = Tensor::new(&[1, 16], vec![v; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    tr
}

/// A request that does NOT opt into profiling must come back with no
/// `"profile"` key in its result envelope at all — the disarmed path
/// leaves result metadata exactly as it was before the profiler existed.
#[test]
fn disarmed_requests_carry_no_profile_block() {
    let server = NdifServer::start(NdifConfig::local(&["tiny-sim"])).unwrap();
    let payload = nnscope::graph::serde::to_json(lens_trace(1.0).graph()).to_string();
    let (status, body) = http::post(server.addr(), "/v1/trace", payload.as_bytes()).unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();
    let (status, body) =
        http::get(server.addr(), &format!("/v1/result/{id}?timeout_ms=30000")).unwrap();
    assert_eq!(status, 200);
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(
        j.get("profile").is_null(),
        "unprofiled result must carry no profile block: {j}"
    );
    // observability itself is still on — timing metadata is unchanged
    assert!(!j.get("timing").is_null());
    // and nothing was pushed into the profile ring
    let (status, _) = http::get(server.addr(), &format!("/v1/debug/profile/{id}")).unwrap();
    assert_eq!(status, 404);
}

/// Header-armed profiling end to end: the result carries the `"profile"`
/// summary, the replica retains a structurally valid Chrome/Perfetto
/// trace, and the hot-op table fills.
#[test]
fn profiled_trace_returns_summary_and_chrome_trace() {
    let server = NdifServer::start(NdifConfig::local(&["tiny-sim"])).unwrap();
    let client = NdifClient::new(server.addr());
    let out = client
        .run(lens_trace(2.0).graph(), nnscope::client::ExecuteOptions::new().profiled())
        .unwrap();
    let (profile, id) = (out.profile.expect("profiled run carries a profile"), out.id);

    assert!(profile.get("ops").as_i64().unwrap_or(0) > 0, "profile: {profile}");
    assert!(profile.get("total_self_us").as_i64().is_some());
    let top = profile.get("top_ops").as_array().unwrap();
    assert!(!top.is_empty());
    for o in top {
        assert!(o.get("op").as_str().is_some());
        assert!(o.get("count").as_i64().unwrap_or(0) > 0);
        assert!(o.get("self_ns").as_i64().unwrap_or(-1) >= 0);
    }
    // the forward pass was recorded as a phase, and memory accounting ran
    assert!(
        profile
            .get("phases")
            .as_array()
            .is_some_and(|ps| ps.iter().any(|p| p.get("name").as_str() == Some("forward"))),
        "profile phases: {profile}"
    );
    assert!(profile.get("alloc_bytes").as_i64().unwrap_or(0) > 0);
    assert!(profile.get("peak_bytes").as_i64().unwrap_or(0) > 0);

    // the retained Chrome trace loads in Perfetto: complete events only,
    // with the fields the trace-event format requires
    let tr = client.profile_trace_events(&id).unwrap();
    let events = tr.get("traceEvents").as_array().unwrap();
    assert!(!events.is_empty());
    for e in events {
        assert_eq!(e.get("ph").as_str(), Some("X"));
        assert!(e.get("name").as_str().is_some());
        assert!(matches!(e.get("cat").as_str(), Some("op") | Some("phase")));
        assert!(e.get("ts").as_i64().unwrap_or(-1) >= 0);
        assert!(e.get("dur").as_f64().unwrap_or(0.0) > 0.0);
        assert!(e.get("pid").as_i64().is_some());
        assert!(e.get("tid").as_i64().is_some());
    }
    assert_eq!(tr.get("otherData").get("request").as_str(), Some(id.as_str()));

    // the replica's cumulative hot-op table saw the request
    let hot = client.hotops().unwrap();
    assert!(hot.get("total_self_ns").as_i64().unwrap_or(0) > 0, "hotops: {hot}");
    assert!(!hot.get("hotops").as_array().unwrap().is_empty());
}

/// The profile ring is bounded and never blocks: 32 concurrent profiled
/// requests against a 4-entry ring all complete, and at most 4 of their
/// Chrome traces are retained afterwards.
#[test]
fn profile_ring_bounded_and_nonblocking_under_concurrency() {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.profile_ring = 4;
    let server = NdifServer::start(cfg).unwrap();
    let addr = server.addr();
    let ids: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..32)
            .map(|i| {
                s.spawn(move || {
                    let client = NdifClient::new(addr);
                    let out = client
                        .run(
                            lens_trace(i as f32).graph(),
                            nnscope::client::ExecuteOptions::new().profiled(),
                        )
                        .unwrap();
                    assert!(
                        out.profile.as_ref().is_some_and(|p| p.get("ops").as_i64().unwrap_or(0) > 0)
                    );
                    out.id
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(ids.len(), 32, "every profiled request must complete");
    let retained = ids
        .iter()
        .filter(|id| {
            let (status, _) =
                http::get(addr, &format!("/v1/debug/profile/{id}")).unwrap();
            status == 200
        })
        .count();
    assert!(retained <= 4, "profile ring exceeded its bound: {retained} retained");
    assert!(retained >= 1, "the most recent profiles must be retained");
}

/// Acceptance: a profiled logit-lens stream's recorded self-times (graph
/// ops + forward/emit phases) must account for the `exec` span within
/// 10% — the profile explains where the time went, it doesn't sample it.
#[test]
fn profiled_stream_self_times_cover_exec_span() {
    let server = NdifServer::start(NdifConfig::local(&["tiny-sim"])).unwrap();
    let mut payload = nnscope::graph::serde::to_json(lens_trace(1.0).graph());
    payload.set("steps", Json::from(32usize));
    payload.set("profile", Json::Bool(true));
    let (status, mut stream) = http::http_request_stream(
        server.addr(),
        "POST",
        "/v1/stream",
        payload.to_string().as_bytes(),
        &[("Content-Type", "application/json")],
        Duration::from_secs(10),
        Duration::from_secs(120),
    )
    .unwrap();
    assert_eq!(status, 200);
    let mut done = None;
    let mut steps = 0usize;
    while let Some(line) = stream.next_line().unwrap() {
        let j = parse(&line).unwrap();
        match j.get("event").as_str() {
            Some("step") => steps += 1,
            Some("done") => {
                done = Some(j);
                break;
            }
            other => panic!("unexpected stream event {other:?}: {line}"),
        }
    }
    let done = done.expect("stream must end with a done event");
    assert_eq!(steps, 32);
    let profile = done.get("profile");
    assert!(!profile.is_null(), "profiled stream must attach a profile: {done}");
    assert!(profile.get("ops").as_i64().unwrap_or(0) > 0);

    let exec_us = done
        .get("timing")
        .get("spans")
        .as_array()
        .unwrap()
        .iter()
        .find(|s| s.get("name").as_str() == Some("exec"))
        .expect("stream timing must include an exec span")
        .get("dur_us")
        .as_i64()
        .unwrap();
    let op_us = profile.get("total_self_us").as_i64().unwrap();
    let phase_us: i64 = profile
        .get("phases")
        .as_array()
        .unwrap()
        .iter()
        .map(|p| p.get("total_us").as_i64().unwrap_or(0))
        .sum();
    let covered = op_us + phase_us;
    let ratio = covered as f64 / exec_us.max(1) as f64;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "recorded self-times ({covered}us ops+phases) must be within 10% of the \
         exec span ({exec_us}us); ratio {ratio:.3}, profile {profile}"
    );
}

fn get_text(addr: std::net::SocketAddr, path: &str) -> String {
    let (status, body) = http::get(addr, path).unwrap();
    assert_eq!(status, 200, "{path}");
    String::from_utf8(body).unwrap()
}

/// Parity: with one replica, the coordinator's
/// `/v1/fleet/metrics?format=prometheus` must emit latency series
/// line-identical to the replica's own `/v1/metrics?format=prometheus` —
/// both render through the same exposition code.
#[test]
fn fleet_prometheus_parity_with_replica() {
    let coord = coordinator(Policy::RoundRobin, Duration::from_millis(50));
    let r1 = replica(&coord);
    let client = NdifClient::new(coord.addr());
    let n = 4u64;
    for i in 0..n {
        run_one(&client, i as f32);
    }
    let (_, c1, _, _) = r1.metrics("tiny-sim").unwrap();
    assert_eq!(c1, n);
    e2e_when_counted(r1.addr(), n);

    let rep = get_text(r1.addr(), "/v1/metrics?format=prometheus");
    let fleet = get_text(coord.addr(), "/v1/fleet/metrics?format=prometheus");
    let latency_lines = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.starts_with("nnscope_latency_seconds"))
            .map(String::from)
            .collect()
    };
    assert_eq!(
        latency_lines(&rep),
        latency_lines(&fleet),
        "fleet exposition must be line-identical to the lone replica's"
    );
    assert!(
        fleet.lines().any(|l| l == "nnscope_fleet_replicas 1"),
        "fleet exposition must carry the replica gauge:\n{fleet}"
    );
}

/// Fleet hot-op aggregation: a request profiled via the body key (which
/// survives coordinator forwarding verbatim) lands in the replica's
/// hot-op table, and `/v1/fleet/hotops` serves the merged view.
#[test]
fn fleet_hotops_aggregate_profiled_requests() {
    let coord = coordinator(Policy::RoundRobin, Duration::from_millis(50));
    let _r1 = replica(&coord);
    let mut payload = nnscope::graph::serde::to_json(lens_trace(1.0).graph());
    payload.set("profile", Json::Bool(true));
    let (status, body) =
        http::post(coord.addr(), "/v1/trace", payload.to_string().as_bytes()).unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();
    let (status, body) =
        http::get(coord.addr(), &format!("/v1/result/{id}?timeout_ms=30000")).unwrap();
    assert_eq!(status, 200);
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(
        !j.get("profile").is_null(),
        "body-armed profiling must survive coordinator forwarding: {j}"
    );

    // the worker folds the hot-op table just after publishing the result
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let hot = get_json(coord.addr(), "/v1/fleet/hotops");
        if hot.get("total_self_ns").as_i64().unwrap_or(0) > 0 {
            assert_eq!(hot.get("replicas").as_i64(), Some(1));
            let ops = hot.get("hotops").as_array().unwrap().to_vec();
            assert!(!ops.is_empty());
            let share: f64 = ops.iter().map(|o| o.get("share").as_f64().unwrap_or(0.0)).sum();
            assert!((share - 1.0).abs() < 1e-9, "shares must sum to 1: {hot}");
            break;
        }
        assert!(Instant::now() < deadline, "fleet hotops never filled: {hot}");
        std::thread::sleep(Duration::from_millis(20));
    }
}
