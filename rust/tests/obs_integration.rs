//! Observability integration: fleet percentile merging, trace-id
//! propagation across coordinator failover, and debug-ring bounding.
//!
//! The load-bearing assertion is bit-identity: a percentile served by the
//! coordinator's `/v1/fleet/metrics` (computed from per-bucket-merged
//! histograms) must equal — `f64::to_bits` equal, not approximately — the
//! percentile computed from the concatenation of the per-replica bucket
//! arrays. That is the property that makes fleet tail latency trustworthy:
//! merging is exact, not an average of averages.

use std::time::{Duration, Instant};

use nnscope::client::remote::NdifClient;
use nnscope::client::Trace;
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::json::{parse, Json};
use nnscope::obs::{percentile_from_counts, HistSnapshot, BUCKETS, TRACE_HEADER};
use nnscope::server::{http, NdifConfig, NdifServer};
use nnscope::tensor::Tensor;

fn coordinator(policy: Policy, probe: Duration) -> Coordinator {
    let mut cfg = CoordinatorConfig::local();
    cfg.policy = policy;
    cfg.probe_interval = probe;
    Coordinator::start(cfg).unwrap()
}

fn replica(coord: &Coordinator) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.coordinator = Some(coord.addr().to_string());
    cfg.heartbeat = Duration::from_millis(50);
    NdifServer::start(cfg).unwrap()
}

fn run_one(client: &NdifClient, v: f32) {
    let tokens = Tensor::new(&[1, 16], vec![v; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    tr.run_remote(client).unwrap();
}

fn get_json(addr: std::net::SocketAddr, path: &str) -> Json {
    let (status, body) = http::get(addr, path).unwrap();
    assert_eq!(status, 200, "{path}: {}", String::from_utf8_lossy(&body));
    parse(std::str::from_utf8(&body).unwrap()).unwrap()
}

/// Replica-side e2e snapshot once it has recorded `want` observations
/// (the worker records histograms just after publishing the result, so a
/// brief wait closes the race with the last client response).
fn e2e_when_counted(addr: std::net::SocketAddr, want: u64) -> HistSnapshot {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = get_json(addr, "/v1/metrics");
        if let Some(h) = HistSnapshot::from_json(j.get("tiny-sim").get("latency").get("e2e")) {
            if h.count >= want {
                return h;
            }
        }
        assert!(Instant::now() < deadline, "replica at {addr} never recorded {want} e2e obs");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn fleet_percentiles_match_concatenated_buckets() {
    let coord = coordinator(Policy::RoundRobin, Duration::from_millis(50));
    let r1 = replica(&coord);
    let r2 = replica(&coord);
    let client = NdifClient::new(coord.addr());
    let n = 6u64;
    for i in 0..n {
        run_one(&client, i as f32);
    }

    // quiesce: both replicas must have banked every observation before the
    // fleet endpoint fans out, and round-robin guarantees both saw traffic
    let (_, c1, _, _) = r1.metrics("tiny-sim").unwrap();
    let (_, c2, _, _) = r2.metrics("tiny-sim").unwrap();
    assert_eq!(c1 + c2, n);
    assert!(c1 >= 1 && c2 >= 1, "round-robin did not spread: {c1}/{c2}");
    let h1 = e2e_when_counted(r1.addr(), c1);
    let h2 = e2e_when_counted(r2.addr(), c2);

    let fleet = get_json(coord.addr(), "/v1/fleet/metrics");
    let m = fleet.get("tiny-sim");
    assert_eq!(m.get("completed").as_i64(), Some(n as i64));
    assert_eq!(fleet.get("_fleet").get("replicas").as_i64(), Some(2));
    let merged = HistSnapshot::from_json(m.get("latency").get("e2e")).unwrap();

    // "concatenating" the per-replica observations is exactly an
    // element-wise sum of their bucket arrays (boundaries are static)
    let mut concat = [0u64; BUCKETS];
    for (slot, (a, b)) in concat.iter_mut().zip(h1.counts.iter().zip(h2.counts.iter())) {
        *slot = a + b;
    }
    assert_eq!(merged.counts, concat, "fleet merge must be the per-bucket sum");
    assert_eq!(merged.count, n);
    for q in [0.5, 0.9, 0.95, 0.99] {
        assert_eq!(
            merged.percentile(q).to_bits(),
            percentile_from_counts(&concat, q).to_bits(),
            "fleet p{} must be bit-identical to the concatenated percentile",
            (q * 100.0) as u32
        );
    }

    // queue-wait and exec histograms merge through the same machinery
    for kind in ["queue_wait", "exec"] {
        let h = HistSnapshot::from_json(m.get("latency").get(kind)).unwrap();
        assert_eq!(h.count, n, "{kind} lost observations in the merge");
    }
}

#[test]
fn trace_id_survives_failover_retry() {
    // slow probe: the monitor must not notice the ghost before the request
    let coord = coordinator(Policy::LeastLoaded, Duration::from_secs(60));
    // a dead replica registered FIRST — least-loaded breaks the 0-load tie
    // by id, so the first routing attempt goes here and fails at transport
    let (status, _) = http::post(
        coord.addr(),
        "/v1/fleet/register",
        br#"{"addr":"127.0.0.1:9","models":["tiny-sim"],"latency_s":0.0}"#,
    )
    .unwrap();
    assert_eq!(status, 200);
    let real = replica(&coord);

    let tokens = Tensor::new(&[1, 16], vec![1.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let payload = nnscope::graph::serde::to_json(tr.graph()).to_string();

    let tid = "deadbeefcafef00d";
    let (status, body) = http::http_request(
        coord.addr(),
        "POST",
        "/v1/trace",
        payload.as_bytes(),
        &[("Content-Type", "application/json"), (TRACE_HEADER, tid)],
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();

    let (status, body) =
        http::get(coord.addr(), &format!("/v1/result/{id}?timeout_ms=30000")).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let timing = j.get("timing");
    // the surviving replica stamped its spans under the id the client
    // minted — the failover retry did NOT re-mint
    assert_eq!(timing.get("trace").as_str(), Some(tid));
    assert_eq!(timing.get("attempts").as_i64(), Some(2), "timing: {timing}");
    assert!(timing.get("coordinator_us").as_i64().unwrap_or(-1) >= 0);
    assert!(
        timing.get("spans").as_array().is_some_and(|s| !s.is_empty()),
        "replica spans missing: {timing}"
    );

    // the coordinator's own debug ring remembers the request by that id
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = get_json(coord.addr(), "/v1/debug/requests");
        let reqs = j.get("requests").as_array().unwrap().to_vec();
        if reqs
            .iter()
            .any(|r| r.get("trace").as_str() == Some(tid) && r.get("attempts").as_i64() == Some(2))
        {
            break;
        }
        assert!(Instant::now() < deadline, "coordinator ring never saw {tid}: {j}");
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(real);
}

#[test]
fn debug_ring_is_bounded() {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.trace_ring = 3;
    let server = NdifServer::start(cfg).unwrap();
    let client = NdifClient::new(server.addr());
    let n = 9;
    for i in 0..n {
        run_one(&client, i as f32);
    }

    // the ring fills to its bound and stays there; the worker pushes just
    // after the result publishes, so wait for the final push
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = get_json(server.addr(), "/v1/debug/requests");
        let reqs = j.get("requests").as_array().unwrap().to_vec();
        assert!(reqs.len() <= 3, "ring exceeded its bound: {} entries", reqs.len());
        if reqs.len() == 3 {
            for r in &reqs {
                assert_eq!(r.get("endpoint").as_str(), Some("trace"));
                assert!(r.get("trace").as_str().is_some_and(|t| !t.is_empty()));
            }
            break;
        }
        assert!(Instant::now() < deadline, "ring never filled: {j}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // client-visible timing: the observed variant surfaces the same spans
    let tokens = Tensor::new(&[1, 16], vec![3.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let (_, _, timing) = client.execute_observed(tr.graph()).unwrap();
    let timing = timing.expect("obs-enabled server must return timing metadata");
    assert!(timing.get("spans").as_array().is_some_and(|s| !s.is_empty()));
}
