//! Session-state integration: server-side variables flowing across traces
//! (store → load → update), validator rejections over the wire, persistent
//! sessions, and coordinator stickiness with replica-death semantics.

use std::time::{Duration, Instant};

use nnscope::client::infabric::{probe_training_session, stable_lr};
use nnscope::client::remote::{is_retryable_session_err, NdifClient};
use nnscope::client::{Session, Trace};
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{http, NdifConfig, NdifServer, StateLimits};
use nnscope::tensor::Tensor;

fn start_server() -> NdifServer {
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&["tiny-sim"]) };
    NdifServer::start(cfg).unwrap()
}

fn tokens() -> Tensor {
    Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect())
}

/// t0 stores 2.0 → `acc`; t1 loads, ×3, stores + saves; t2 loads, +1,
/// saves. A three-trace chain whose results prove cross-trace dataflow.
fn accumulator_session() -> (Session, nnscope::client::SavedRef, nnscope::client::SavedRef) {
    let mut session = Session::new();
    let mut t0 = Trace::new("tiny-sim", &tokens());
    let c = t0.constant(&Tensor::scalar(2.0));
    t0.save_to_state("acc", c);
    session.add(t0);
    let mut t1 = Trace::new("tiny-sim", &tokens());
    let a = t1.from_state("acc");
    let a3 = t1.scale(a, 3.0);
    t1.save_to_state("acc", a3);
    let s1 = t1.save(a3);
    session.add(t1);
    let mut t2 = Trace::new("tiny-sim", &tokens());
    let a = t2.from_state("acc");
    let one = t2.constant(&Tensor::scalar(1.0));
    let sum = t2.add(a, one);
    let s2 = t2.save(sum);
    session.add(t2);
    (session, s1, s2)
}

#[test]
fn state_flows_across_three_traces_remote_and_local() {
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let (session, s1, s2) = accumulator_session();
    let results = session.run_remote(&client).unwrap();
    assert_eq!(results.len(), 3);
    assert_eq!(results[1].get(s1).item(), 6.0);
    assert_eq!(results[2].get(s2).item(), 7.0);

    // the local path threads state identically
    let runner =
        nnscope::models::ModelRunner::load(&nnscope::models::artifacts_dir(), "tiny-sim").unwrap();
    let (session, s1, s2) = accumulator_session();
    let results = session.run_local(&runner).unwrap();
    assert_eq!(results[1].get(s1).item(), 6.0);
    assert_eq!(results[2].get(s2).item(), 7.0);
}

#[test]
fn load_before_store_rejected_at_submit() {
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let mut session = Session::new();
    let mut t0 = Trace::new("tiny-sim", &tokens());
    let w = t0.from_state("never-stored");
    t0.save(w);
    session.add(t0);
    let err = session.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("load-before-store"), "{err}");
}

#[test]
fn state_ops_rejected_on_trace_endpoint() {
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let mut tr = Trace::new("tiny-sim", &tokens());
    let c = tr.constant(&Tensor::scalar(1.0));
    tr.save_to_state("w", c);
    let err = tr.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("/v1/session"), "{err}");
}

#[test]
fn persistent_session_survives_requests_until_dropped() {
    let server = start_server();
    let client = NdifClient::new(server.addr());

    // request 1: store
    let mut session = Session::new().with_id("probe-42");
    let mut t0 = Trace::new("tiny-sim", &tokens());
    let c = t0.constant(&Tensor::full(&[2], 5.0));
    t0.save_to_state("w", c);
    session.add(t0);
    session.run_remote(&client).unwrap();

    // state is observable between requests
    let (keys, bytes, _idle) = client.session_info("probe-42").unwrap();
    assert_eq!(keys, vec!["w".to_string()]);
    assert_eq!(bytes, 8);

    // request 2: load continues from the stored value
    let mut session = Session::new().with_id("probe-42");
    let mut t1 = Trace::new("tiny-sim", &tokens());
    let w = t1.from_state("w");
    let s = t1.save(w);
    session.add(t1);
    let results = session.run_remote(&client).unwrap();
    assert_eq!(results[0].get(s).data(), &[5.0, 5.0]);

    // drop, then the key is gone (load-before-store again)
    assert!(client.drop_session("probe-42").unwrap());
    assert!(client.session_info("probe-42").is_err());
    let mut session = Session::new().with_id("probe-42");
    let mut t = Trace::new("tiny-sim", &tokens());
    let w = t.from_state("w");
    t.save(w);
    session.add(t);
    assert!(session.run_remote(&client).is_err());
}

#[test]
fn anonymous_namespace_is_reserved() {
    // a client-named session may not squat the generated-id namespace
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let mut session = Session::new().with_id("es-1");
    let mut t = Trace::new("tiny-sim", &tokens());
    let c = t.constant(&Tensor::scalar(1.0));
    t.save_to_state("w", c);
    session.add(t);
    let err = session.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("reserved"), "{err}");
}

#[test]
fn sessions_cannot_read_each_others_state() {
    let server = start_server();
    let client = NdifClient::new(server.addr());

    let mut session = Session::new().with_id("alice");
    let mut t = Trace::new("tiny-sim", &tokens());
    let c = t.constant(&Tensor::scalar(1.0));
    t.save_to_state("secret", c);
    session.add(t);
    session.run_remote(&client).unwrap();

    // a different session loading alice's key fails validation
    let mut session = Session::new().with_id("mallory");
    let mut t = Trace::new("tiny-sim", &tokens());
    let w = t.from_state("secret");
    t.save(w);
    session.add(t);
    let err = session.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("load-before-store"), "{err}");

    // ...and so does an anonymous (ephemeral) session
    let mut session = Session::new();
    let mut t = Trace::new("tiny-sim", &tokens());
    let w = t.from_state("secret");
    t.save(w);
    session.add(t);
    assert!(session.run_remote(&client).is_err());
}

#[test]
fn session_lifecycle_endpoints_respect_model_auth() {
    use std::collections::HashMap;
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.auth = HashMap::from([("tiny-sim".to_string(), vec!["sesame".to_string()])]);
    let server = NdifServer::start(cfg).unwrap();
    let authed = NdifClient::new(server.addr()).with_token("sesame");

    let mut session = Session::new().with_id("gated");
    let mut t = Trace::new("tiny-sim", &tokens());
    let c = t.constant(&Tensor::scalar(1.0));
    t.save_to_state("w", c);
    session.add(t);
    session.run_remote(&authed).unwrap();

    // no token: neither inspect nor destroy another client's state
    let anon = NdifClient::new(server.addr());
    assert!(anon.session_info("gated").is_err());
    let (status, _) = http::http_request(
        server.addr(),
        "DELETE",
        "/v1/session/gated",
        b"",
        &[],
    )
    .unwrap();
    assert_eq!(status, 401);
    // the state is still there for the authorized owner
    let (keys, _, _) = authed.session_info("gated").unwrap();
    assert_eq!(keys, vec!["w".to_string()]);
    assert!(authed.drop_session("gated").unwrap());
}

#[test]
fn state_byte_budget_fails_session_cleanly() {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.state_limits = StateLimits { max_bytes_per_session: 8, ..Default::default() };
    let server = NdifServer::start(cfg).unwrap();
    let client = NdifClient::new(server.addr());
    let mut session = Session::new();
    let mut t = Trace::new("tiny-sim", &tokens());
    let c = t.constant(&Tensor::full(&[16], 1.0)); // 64 B > 8 B cap
    t.save_to_state("w", c);
    session.add(t);
    let err = session.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("budget"), "{err}");
}

#[test]
fn in_fabric_training_loop_single_request_reduces_loss() {
    // the probe_training example's core, as an assertion: a 5-step SGD
    // loop whose parameters live entirely in session state
    let server = start_server();
    let client = NdifClient::new(server.addr());
    let (d, steps) = (32usize, 5usize);

    // stable step size from the activation scale
    let mut tr = Trace::new("tiny-sim", &tokens());
    let h0 = tr.output("layer.0");
    let s0 = tr.save(h0);
    let res = tr.run_remote(&client).unwrap();
    let lr = stable_lr(res.get(s0), 0.5);

    let mut w0 = Tensor::zeros(&[d, d]);
    let mut rng = nnscope::util::Prng::new(8);
    rng.fill_uniform_sym(w0.data_mut(), 0.05);
    let b0 = Tensor::zeros(&[d]);

    let plan = probe_training_session(
        "tiny-sim",
        &tokens(),
        ("layer.0", "layer.1"),
        steps,
        lr,
        (&w0, &b0),
    );
    let results = plan.session.run_remote(&client).unwrap();
    let losses: Vec<f32> = plan
        .loss_saves
        .iter()
        .zip(&results)
        .map(|(s, r)| r.get(*s).item())
        .collect();
    assert!(
        losses[steps - 1] < losses[0],
        "in-fabric SGD failed to reduce loss: {losses:?}"
    );
    // the final trace returns the trained parameters
    let final_res = results.last().unwrap();
    assert_eq!(final_res.get(plan.w_save).dims(), &[d, d]);
    assert_eq!(final_res.get(plan.b_save).dims(), &[d]);
}

// ---------------------------------------------------------------------------
// Coordinator stickiness
// ---------------------------------------------------------------------------

fn coordinator() -> Coordinator {
    let mut cfg = CoordinatorConfig::local();
    cfg.policy = Policy::RoundRobin;
    cfg.probe_interval = Duration::from_millis(50);
    cfg.health.degraded_after = Duration::from_millis(400);
    cfg.health.dead_after = Duration::from_secs(2);
    Coordinator::start(cfg).unwrap()
}

fn replica(coord: &Coordinator) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.coordinator = Some(coord.addr().to_string());
    cfg.heartbeat = Duration::from_millis(50);
    NdifServer::start(cfg).unwrap()
}

fn store_via(client: &NdifClient, session_id: &str, v: f32) -> anyhow::Result<()> {
    let mut session = Session::new().with_id(session_id);
    let mut t = Trace::new("tiny-sim", &tokens());
    let c = t.constant(&Tensor::scalar(v));
    t.save_to_state("w", c);
    session.add(t);
    session.run_remote(client).map(|_| ())
}

fn load_via(client: &NdifClient, session_id: &str) -> anyhow::Result<f32> {
    let mut session = Session::new().with_id(session_id);
    let mut t = Trace::new("tiny-sim", &tokens());
    let w = t.from_state("w");
    let s = t.save(w);
    session.add(t);
    let results = session.run_remote(client)?;
    Ok(results[0].get(s).item())
}

#[test]
fn coordinator_pins_sessions_and_surfaces_replica_death_as_retryable() {
    let coord = coordinator();
    let r1 = replica(&coord);
    let r2 = replica(&coord);
    let client = NdifClient::new(coord.addr());

    store_via(&client, "sticky", 9.0).unwrap();
    // follow-up bundles land on the state-holding replica — a mis-route
    // would fail validation with load-before-store
    for _ in 0..3 {
        assert_eq!(load_via(&client, "sticky").unwrap(), 9.0);
    }

    // find and kill the replica holding the state
    let mut replicas = [r1, r2];
    let holder = replicas
        .iter()
        .position(|r| matches!(http::get(r.addr(), "/v1/session/sticky"), Ok((200, _))))
        .expect("some replica holds the session state");
    replicas[holder].kill();

    // the session must now fail with a clean retryable error — not hang,
    // not silently reroute to a replica that never saw the parameters
    let err = load_via(&client, "sticky").unwrap_err();
    assert!(is_retryable_session_err(&err), "{err}");

    // once the registry notices the death, fresh sessions place on the
    // survivor (fresh sticky placement does not fail over mid-request, so
    // wait out the health transition instead of racing it)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let status = client.fleet_status().unwrap();
        let dead = status
            .get("replicas")
            .as_array()
            .unwrap()
            .iter()
            .filter(|r| r.get("health").as_str() == Some("dead"))
            .count();
        if dead >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "registry never marked the replica dead");
        std::thread::sleep(Duration::from_millis(50));
    }
    store_via(&client, "sticky2", 4.0).unwrap();
    assert_eq!(load_via(&client, "sticky2").unwrap(), 4.0);
}
