//! Golden parity suite for the continuous-batching decode engine.
//!
//! The engine's bit-parity contract (see `engine::model`): every matmul
//! and attention mix is computed per row with an identical reduction
//! order regardless of how many rows or streams are in flight. These
//! tests hold that contract with `assert_eq!` — no tolerances:
//!
//! * batched continuous decode ≡ sequential solo decode, for 1, 2 and 8
//!   concurrent sequences, under staggered admission and mid-batch
//!   completion, parallel and sequential stepping alike;
//! * per-sequence KV-cached decode ≡ full-prefix recompute;
//! * setter interventions stay scoped to their own sequence inside a
//!   batch, and per-step hook values are unchanged by batching.
//!
//! Everything here runs on `engine::NativeModel` over a synthetic
//! manifest — no artifacts, no server.

use nnscope::client::Trace;
use nnscope::engine::{ContinuousBatch, KvStream, NativeModel};
use nnscope::graph::{GraphResult, InterventionGraph};
use nnscope::models::generate::{argmax_row, Generation};
use nnscope::models::NoHooks;
use nnscope::runtime::artifacts::Manifest;
use nnscope::tensor::Tensor;

fn model() -> NativeModel {
    NativeModel::new(Manifest::synthetic("parity-test", 32, 3, 4, 64, 29, 48))
}

/// A stream graph with a per-step hook on the last layer's mean — every
/// step must emit it, batched or not.
fn hooked_graph(m: &NativeModel, prompt: &[f32]) -> InterventionGraph {
    let t = Tensor::new(&[1, prompt.len()], prompt.to_vec());
    let mut tr = Trace::new(&m.manifest().name, &t);
    let h = tr.output("layer.2");
    let mean = tr.mean(h);
    tr.step_hook(mean);
    tr.into_graph()
}

/// A stream graph that additionally *steers*: layer.0's output is scaled,
/// which changes every downstream activation and (generically) the
/// trajectory.
fn steered_graph(m: &NativeModel, prompt: &[f32], factor: f32) -> InterventionGraph {
    let t = Tensor::new(&[1, prompt.len()], prompt.to_vec());
    let mut tr = Trace::new(&m.manifest().name, &t);
    let h = tr.output("layer.0");
    let z = tr.scale(h, factor);
    tr.set_output("layer.0", z);
    let l = tr.output("layer.2");
    let mean = tr.mean(l);
    tr.step_hook(mean);
    tr.into_graph()
}

fn prompts(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..=(i % 4) + 1).map(|j| ((i * 7 + j * 3) % 29) as f32).collect())
        .collect()
}

/// Solo oracle: run one stream to completion, collecting the full
/// trajectory and every step's hook values.
fn solo(
    m: &NativeModel,
    graph: InterventionGraph,
    steps: usize,
) -> (Generation, Vec<GraphResult>) {
    let mut s = KvStream::new(graph, m, steps).unwrap();
    let mut values = Vec::new();
    while let Some(out) = s.step(m).unwrap() {
        values.push(out.values);
    }
    (s.into_generation(), values)
}

/// Batched run: staggered admission (stream i joins at tick i/2),
/// mid-batch retirement (steps differ per stream), events collected per
/// stream id.
fn batched(
    m: &NativeModel,
    graphs: Vec<InterventionGraph>,
    steps: &[usize],
    parallel: bool,
) -> Vec<(Vec<usize>, Vec<f32>, Vec<GraphResult>)> {
    let mut batch = ContinuousBatch::new();
    for (i, g) in graphs.into_iter().enumerate() {
        batch.admit_at((i / 2) as u64, i, KvStream::new(g, m, steps[i]).unwrap());
    }
    let mut got: Vec<(Vec<usize>, Vec<f32>, Vec<GraphResult>)> =
        (0..steps.len()).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
    batch
        .run(parallel, |s: &mut KvStream| s.step(m), &mut |id, out| {
            got[id].0.push(out.token);
            got[id].1.push(out.score);
            got[id].2.push(out.values);
        })
        .unwrap();
    got
}

fn assert_stream_parity(
    i: usize,
    oracle: &(Generation, Vec<GraphResult>),
    got: &(Vec<usize>, Vec<f32>, Vec<GraphResult>),
) {
    assert_eq!(got.0, oracle.0.tokens, "stream {i}: tokens diverged under batching");
    assert_eq!(got.1, oracle.0.scores, "stream {i}: scores diverged under batching");
    assert_eq!(got.2.len(), oracle.1.len(), "stream {i}: step count diverged");
    for (step, (a, b)) in got.2.iter().zip(&oracle.1).enumerate() {
        assert_eq!(
            a.values, b.values,
            "stream {i} step {step}: hook values diverged under batching"
        );
    }
}

/// The tentpole acceptance case: batched continuous decode is
/// bit-identical to sequential for 1, 2 and 8 concurrent sequences, with
/// staggered admission and mid-batch completion, under both sequential
/// and parallel per-tick stepping.
#[test]
fn batched_decode_bit_identical_to_sequential_for_1_2_8_streams() {
    let m = model();
    for n in [1usize, 2, 8] {
        let ps = prompts(n);
        // steps differ per stream so short ones retire mid-batch
        let steps: Vec<usize> = (0..n).map(|i| 2 + (i * 3) % 7).collect();
        let oracles: Vec<_> = ps
            .iter()
            .zip(&steps)
            .map(|(p, &st)| solo(&m, hooked_graph(&m, p), st))
            .collect();
        for parallel in [false, true] {
            let got = batched(
                &m,
                ps.iter().map(|p| hooked_graph(&m, p)).collect(),
                &steps,
                parallel,
            );
            for (i, (o, g)) in oracles.iter().zip(&got).enumerate() {
                assert_stream_parity(i, o, g);
            }
        }
    }
}

/// A stream finishing mid-batch must not perturb survivors: the long
/// stream's trajectory is identical whether it shared ticks with a
/// short-lived neighbour or ran alone.
#[test]
fn mid_batch_retirement_leaves_survivors_bit_identical() {
    let m = model();
    let long_prompt = [3.0, 11.0, 5.0];
    let (long_solo, long_vals) = solo(&m, hooked_graph(&m, &long_prompt), 9);
    let got = batched(
        &m,
        vec![hooked_graph(&m, &[8.0, 2.0]), hooked_graph(&m, &long_prompt)],
        &[2, 9],
        true,
    );
    assert_eq!(got[0].0.len(), 2, "short stream must emit exactly its 2 steps");
    assert_stream_parity(1, &(long_solo, long_vals), &got[1]);
}

/// Setter interventions are per-sequence: a steered stream batched with a
/// plain one leaves the plain one untouched, and the steered one matches
/// its own solo oracle. The steering itself must be doing something —
/// the two trajectories differ.
#[test]
fn setter_effects_stay_scoped_to_their_own_sequence() {
    let m = model();
    let prompt = [1.0, 6.0, 4.0, 2.0];
    let steps = 6;
    let plain_oracle = solo(&m, hooked_graph(&m, &prompt), steps);
    let steered_oracle = solo(&m, steered_graph(&m, &prompt, 0.0), steps);
    let steering_observable = steered_oracle
        .1
        .iter()
        .zip(&plain_oracle.1)
        .any(|(a, b)| a.values != b.values);
    assert!(steering_observable, "zeroing layer.0 must change downstream hook values");

    let got = batched(
        &m,
        vec![steered_graph(&m, &prompt, 0.0), hooked_graph(&m, &prompt)],
        &[steps, steps],
        true,
    );
    assert_stream_parity(0, &steered_oracle, &got[0]);
    assert_stream_parity(1, &plain_oracle, &got[1]);
}

/// KV-cached decode against a full-prefix recompute oracle: after every
/// decode step, a fresh prefill over the whole extended token sequence
/// must produce the same greedy choice bit-for-bit. This is the property
/// that makes the O(1)-per-step cache admissible at all.
#[test]
fn kv_cached_trajectory_matches_full_recompute_oracle() {
    let m = model();
    let prompt_f = [2.0, 9.0, 1.0];
    let steps = 8;
    let mut s = KvStream::new(hooked_graph(&m, &prompt_f), &m, steps).unwrap();
    let mut kv_traj = Vec::new();
    while let Some(out) = s.step(&m).unwrap() {
        kv_traj.push((out.token, out.score));
    }

    // oracle: no cache reuse — re-prefill the full prefix from scratch at
    // every step (quadratic, which is exactly why the engine doesn't)
    let vocab = m.manifest().vocab;
    let mut toks: Vec<usize> = prompt_f.iter().map(|&t| t as usize).collect();
    let mut oracle_traj = Vec::new();
    for _ in 0..steps {
        let mut cache = m.kv_cache();
        let logits = m.prefill(&toks, &mut cache, &mut NoHooks).unwrap();
        let data = logits.data();
        let (t, sc) = argmax_row(&data[data.len() - vocab..]);
        oracle_traj.push((t, sc));
        toks.push(t);
    }
    assert_eq!(kv_traj, oracle_traj, "KV-cached decode diverged from full recompute");
}

/// Per-decode-step cached state grows by exactly one position per step
/// and never re-runs earlier positions — the O(1) work-per-step shape,
/// asserted structurally (the wall-clock version lives in
/// `benches/decode.rs`).
#[test]
fn cache_grows_one_position_per_step() {
    let m = model();
    let prompt = [4.0, 4.0, 7.0, 1.0, 0.0];
    let mut s = KvStream::new(hooked_graph(&m, &prompt), &m, 5).unwrap();
    s.step(&m).unwrap(); // prefill
    assert_eq!(s.cached_len(), prompt.len());
    for i in 1..5 {
        s.step(&m).unwrap();
        assert_eq!(s.cached_len(), prompt.len() + i, "step {i} must append exactly one row");
    }
    assert!(s.finished());
}
