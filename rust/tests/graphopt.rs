//! Optimizer parity and admission-compiler integration tests.
//!
//! The compiler's contract is that every value the user asked for is
//! **bit-identical** with and without optimization — across plain traces,
//! co-tenant merges, streaming re-execution, and session state ops. The
//! property tests here generate randomized graphs (duplicate getters,
//! const subtrees, fusable chains, speculative dead reads, setters,
//! grads) and hold the optimized execution to exact equality against the
//! raw interpreter. The server-level tests pin the admission behavior:
//! folding failures are clean 400s, `/v1/result` carries the `"opt"`
//! report, and `optimize: false` restores the uncompiled path.

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::engine::{Engine, ExecSpec};
use nnscope::graph::{opt, InterventionGraph};
use nnscope::interp;
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::Prng;

fn runner() -> ModelRunner {
    ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap()
}

/// A randomized graph exercising every optimizer pass: duplicate getter
/// reads (CSE), const-only subtrees (folding), chains nothing consumes
/// (DCE), add-of-scale / softmax-of-scale / gelu-of-matmul shapes
/// (fusion), optional setters and grads.
fn random_graph(rng: &mut Prng, seq: usize, vocab: usize, n_layers: usize) -> InterventionGraph {
    let batch = 1;
    let tokens = Tensor::new(
        &[batch, seq],
        (0..batch * seq).map(|_| rng.range(0, vocab) as f32).collect(),
    );
    let mut tr = Trace::new("tiny-sim", &tokens);
    let layer = rng.range(0, n_layers);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    // a duplicate read of the same point (CSE fodder)
    let h_dup = tr.output(&point);
    // a const-only subtree (folding fodder)
    let c1 = tr.constant(&Tensor::new(&[4, 4], (0..16).map(|i| (i as f32 - 8.0) * 0.3).collect()));
    let c2 = tr.constant(&Tensor::new(&[4, 4], (0..16).map(|i| (i as f32).sin()).collect()));
    let cm = tr.matmul(c1, c2);
    let cs = tr.softmax(cm);
    if rng.below(2) == 0 {
        tr.save(cs);
    } // else: the whole const subtree is dead (DCE fodder)
    // a speculative getter nobody consumes
    let _dead = tr.output(&format!("layer.{}", rng.range(0, n_layers)));
    // a fusable chain over the activation
    let mut cur = h;
    for _ in 0..rng.range(0, 4) {
        cur = match rng.range(0, 5) {
            0 => {
                let sc = tr.scale(h_dup, 0.25 + rng.uniform_f32());
                tr.add(cur, sc) // Add-of-Scale → FusedScaleAdd
            }
            1 => {
                let sc = tr.scale(cur, 1.0 + rng.uniform_f32());
                tr.softmax(sc) // Softmax-of-Scale → FusedScaleSoftmax
            }
            2 => tr.gelu(cur),
            3 => tr.fill(cur, &[Range1::one(0), Range1::one(seq - 1)], rng.uniform_f32()),
            _ => tr.scale(cur, 0.5 + rng.uniform_f32()),
        };
    }
    if rng.below(3) == 0 {
        tr.set_output(&point, cur);
    }
    // grads on some graphs (post-phase parity; dead grads also exercise
    // DCE skipping the backward pass)
    if rng.below(3) == 0 {
        tr.targets(&[1.0]);
        let g = tr.grad(&format!("layer.{}", rng.range(0, n_layers)));
        if rng.below(2) == 0 {
            let ng = tr.scale(g, -1.0);
            tr.save(ng);
        }
    }
    let later = tr.output(&format!("layer.{}", rng.range(layer, n_layers)));
    let m = tr.mean(later);
    tr.save(m);
    tr.save(cur);
    tr.into_graph()
}

#[test]
fn optimized_traces_are_bit_identical_to_raw() {
    let r = runner();
    let m = r.manifest.clone();
    let mut rng = Prng::new(0x0717);
    let mut optimizer_did_something = false;
    for case in 0..30 {
        let g = random_graph(&mut rng, m.seq, m.vocab, m.n_layers);
        let eng = Engine::new(&r);
        let raw = eng.run(ExecSpec::raw(&g));
        let opt = eng.run(ExecSpec::trace(&g));
        match (raw, opt) {
            (Ok(raw), Ok(opt)) => {
                let report = opt.report.expect("optimized path must report");
                assert_eq!(report.nodes_before, g.nodes.len(), "case {case}");
                if report.nodes_after < report.nodes_before {
                    optimizer_did_something = true;
                }
                let (raw, opt) = (raw.result, opt.result);
                assert_eq!(
                    raw.values.keys().collect::<Vec<_>>(),
                    opt.values.keys().collect::<Vec<_>>(),
                    "case {case}: saved-id sets differ"
                );
                for (id, t) in &raw.values {
                    assert_eq!(t, &opt.values[id], "case {case} node {id}: values differ");
                }
            }
            (Err(_), Err(_)) => {} // parity on failure is parity too
            (raw, opt) => panic!(
                "case {case}: raw {:?} vs optimized {:?} disagree on success",
                raw.map(|_| ()),
                opt.map(|_| ())
            ),
        }
    }
    assert!(optimizer_did_something, "workload never triggered a rewrite");
}

#[test]
fn optimized_streams_are_bit_identical_to_raw() {
    let r = runner();
    let m = r.manifest.clone();
    let mut rng = Prng::new(0x57EA);
    for case in 0..6 {
        let tokens = Tensor::new(
            &[1, m.seq],
            (0..m.seq).map(|_| rng.range(0, m.vocab) as f32).collect(),
        );
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        // const subtree re-evaluated per step unoptimized, folded once
        // optimized — values must still agree exactly
        let c = tr.constant(&Tensor::new(&[4], vec![0.5, -1.0, 2.0, 0.25]));
        let cs = tr.softmax(c);
        let cm = tr.mean(cs);
        tr.step_hook(cm);
        let sc = tr.scale(h, 2.0);
        let sm = tr.softmax(sc); // fusable
        let mn = tr.mean(sm);
        tr.step_hook(mn);
        let _dead = tr.output("layer.1");
        if rng.below(2) == 0 {
            let z = tr.scale(h, 0.5);
            tr.set_output("layer.0", z);
        }
        let g = tr.into_graph();

        let steps = 4;
        let mut raw_events = Vec::new();
        let mut raw_sink = |step: usize, out: interp::StepOutcome| {
            raw_events.push((step, out.token, out.values.values.clone()));
            true
        };
        let eng = Engine::new(&r);
        let raw_out = eng.run_streaming(ExecSpec::raw(&g).stream(steps), &mut raw_sink).unwrap();
        let raw_gen = raw_out.generation.expect("streaming run yields a generation");
        assert!(raw_out.report.is_none());
        let mut opt_events = Vec::new();
        let mut opt_sink = |step: usize, out: interp::StepOutcome| {
            opt_events.push((step, out.token, out.values.values.clone()));
            true
        };
        let opt_out = eng.run_streaming(ExecSpec::trace(&g).stream(steps), &mut opt_sink).unwrap();
        let opt_gen = opt_out.generation.expect("streaming run yields a generation");
        let report = opt_out.report.expect("optimized stream must report");
        assert!(report.nodes_after < report.nodes_before, "case {case}");
        assert_eq!(raw_gen.tokens, opt_gen.tokens, "case {case}");
        assert_eq!(raw_gen.scores, opt_gen.scores, "case {case}");
        assert_eq!(raw_events, opt_events, "case {case}: per-step values differ");
    }
}

#[test]
fn optimized_sessions_are_bit_identical_to_raw() {
    let r = runner();
    let m = r.manifest.clone();
    let tokens = Tensor::new(&[1, m.seq], vec![1.0; m.seq]);
    // trace 0: store a getter-derived value; trace 1: load, fusable
    // update, store back + save; trace 2: load + save
    let build = || {
        let mut t0 = Trace::new("tiny-sim", &tokens);
        let h = t0.output("layer.0");
        let flat = t0.mean_axis(h, 0);
        t0.save_to_state("acc", flat);
        let mut t1 = Trace::new("tiny-sim", &tokens);
        let a = t1.from_state("acc");
        let a2 = t1.from_state("acc"); // CSE fodder
        let sc = t1.scale(a2, 0.5);
        let upd = t1.add(a, sc); // FusedScaleAdd fodder
        t1.save_to_state("acc", upd);
        t1.save(upd);
        let mut t2 = Trace::new("tiny-sim", &tokens);
        let a = t2.from_state("acc");
        let mn = t2.mean(a);
        t2.save(mn);
        vec![t0.into_graph(), t1.into_graph(), t2.into_graph()]
    };
    let graphs = build();
    let run = |optimize: bool| {
        let mut state = interp::StateView::new();
        let results = Engine::new(&r).run_session(&graphs, &mut state, optimize).unwrap();
        (results, state)
    };
    let (raw_res, raw_state) = run(false);
    let (opt_res, opt_state) = run(true);
    for (i, (raw, opt)) in raw_res.iter().zip(&opt_res).enumerate() {
        assert_eq!(raw.values, opt.values, "trace {i} saved values diverged");
    }
    assert!(!raw_res[1].values.is_empty() && !raw_res[2].values.is_empty());
    assert_eq!(raw_state.len(), opt_state.len());
    for (k, v) in &raw_state {
        assert_eq!(v, &opt_state[k], "state key {k} diverged");
    }
}

#[test]
fn optimized_cotenant_merges_match_raw_merges() {
    use nnscope::scheduler::execute_merged;
    let r = runner();
    let m = r.manifest.clone();
    let mut rng = Prng::new(0xC0DE);
    for case in 0..5 {
        // two single-row CSE-heavy graphs that fit one exported batch
        let mut graphs = Vec::new();
        for _ in 0..2 {
            let tokens = Tensor::new(
                &[1, m.seq],
                (0..m.seq).map(|_| rng.range(0, m.vocab) as f32).collect(),
            );
            let mut tr = Trace::new("tiny-sim", &tokens);
            for _ in 0..3 {
                let h = tr.output("layer.0"); // duplicate reads
                let sc = tr.scale(h, 2.0);
                let sm = tr.softmax(sc);
                let mn = tr.mean(sm);
                tr.save(mn);
            }
            graphs.push(tr.into_graph());
        }
        let fseq = m.forward_sequence();
        let optimized: Vec<opt::Optimized> = graphs
            .iter()
            .map(|g| opt::optimize(g, &fseq).unwrap())
            .collect();
        let raw_merged = execute_merged(&graphs, &r).unwrap();
        let opt_graphs: Vec<InterventionGraph> =
            optimized.iter().map(|o| o.graph.clone()).collect();
        let opt_merged = execute_merged(&opt_graphs, &r).unwrap();
        for (i, (o, (raw, opt_res))) in optimized
            .iter()
            .zip(raw_merged.iter().zip(opt_merged))
            .enumerate()
        {
            let raw = raw.as_ref().unwrap();
            let remapped = o.remap_result(opt_res.unwrap());
            assert_eq!(raw.values.len(), remapped.values.len(), "case {case} graph {i}");
            for (id, t) in &raw.values {
                assert_eq!(t, &remapped.values[id], "case {case} graph {i} node {id}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Server-level admission behavior
// ---------------------------------------------------------------------------

fn start_server(optimize: bool) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.optimize = optimize;
    NdifServer::start(cfg).unwrap()
}

fn probe_trace(tokens: &Tensor) -> (Trace, nnscope::client::SavedRef) {
    let mut tr = Trace::new("tiny-sim", tokens);
    let h = tr.output("layer.0");
    let h2 = tr.output("layer.0"); // duplicate live read: CSE at admission
    let sc = tr.scale(h2, 2.0);
    let sm = tr.softmax(sc); // Softmax-of-Scale: fused at admission
    let mn = tr.mean(sm);
    let s = tr.save(mn);
    let mn2 = tr.mean(h); // keeps the first read live too
    tr.save(mn2);
    let _dead = tr.gelu(h); // dead chain: DCE at admission
    (tr, s)
}

#[test]
fn result_metadata_carries_opt_report_and_no_opt_omits_it() {
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());

    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let (tr, s) = probe_trace(&tokens);
    let graph = tr.graph().clone();
    let res = tr.run_remote(&client).unwrap();
    let report = *res.opt_report().expect("optimizing server must attach an opt report");
    assert_eq!(report.nodes_before, graph.nodes.len());
    assert!(report.nodes_after < report.nodes_before);
    assert!(report.dce_removed >= 1);
    assert!(report.cse_merged >= 1);
    let optimized_value = res.get(s).clone();
    drop(server);

    let server = start_server(false);
    let client = NdifClient::new(server.addr());
    let (tr, s2) = probe_trace(&tokens);
    let res = tr.run_remote(&client).unwrap();
    assert!(res.opt_report().is_none(), "--no-opt must omit the report");
    assert_eq!(&optimized_value, res.get(s2), "values must not depend on the compiler");
}

#[test]
fn empty_const_reduction_is_a_clean_400_at_admission() {
    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let c = tr.constant(&Tensor::new(&[4], vec![1.0; 4]));
    let empty = tr.slice(c, &[Range1::new(2, 2)]);
    let m = tr.mean(empty);
    tr.save(m);
    let err = tr.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("400"), "expected admission 400, got: {err}");
    assert!(err.contains("empty"), "error must name the empty reduction: {err}");
}

#[test]
fn non_const_empty_reduction_fails_execution_not_nan() {
    // an activation sliced to zero rows cannot be caught at admission
    // (its shape is only known at execution) — it must fail with a clear
    // message instead of returning NaN
    let r = runner();
    let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let empty = tr.slice(h, &[Range1::new(0, 0)]);
    let m = tr.mean(empty);
    tr.save(m);
    let err = tr.run_local(&r).unwrap_err().to_string();
    assert!(err.contains("empty"), "{err}");
}

#[test]
fn streamed_values_do_not_depend_on_the_compiler() {
    use nnscope::client::remote::StreamEvent;
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 5) as f32).collect());
    let build = || {
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        let sc = tr.scale(h, 3.0);
        let sm = tr.softmax(sc);
        let mn = tr.mean(sm);
        tr.step_hook(mn);
        let _dead = tr.output("layer.1");
        tr
    };
    let mut collect = |optimize: bool| {
        let server = start_server(optimize);
        let client = NdifClient::new(server.addr());
        let mut steps = Vec::new();
        for ev in build().run_stream(&client, 3).unwrap() {
            match ev.unwrap() {
                StreamEvent::Step { step, token, values, .. } => {
                    steps.push((step, token, values.values))
                }
                StreamEvent::Done { tokens, .. } => assert_eq!(tokens.len(), 3),
            }
        }
        steps
    };
    let with_opt = collect(true);
    let without = collect(false);
    assert_eq!(with_opt, without, "per-step streamed values must not depend on the compiler");
}

#[test]
fn session_endpoint_compiles_stateful_bundles() {
    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let tokens = Tensor::new(&[1, 16], vec![2.0; 16]);
    let mut t0 = Trace::new("tiny-sim", &tokens);
    let c = t0.constant(&Tensor::scalar(2.0));
    let c2 = t0.constant(&Tensor::scalar(3.0));
    let folded = t0.mul(c, c2); // folds to 6.0 at admission
    t0.save_to_state("acc", folded);
    let mut t1 = Trace::new("tiny-sim", &tokens);
    let a = t1.from_state("acc");
    t1.save(a);
    let results = client
        .run_session(
            &[t0.into_graph(), t1.into_graph()],
            None,
            nnscope::client::ExecuteOptions::new(),
        )
        .unwrap();
    assert_eq!(results[1].values.values().next().unwrap().item(), 6.0);

    // a folding failure inside a bundle names the trace, as a 400
    let mut bad = Trace::new("tiny-sim", &tokens);
    let c = bad.constant(&Tensor::new(&[2], vec![1.0, 2.0]));
    let empty = bad.slice(c, &[Range1::new(1, 1)]);
    let m = bad.sum(empty);
    bad.save_to_state("x", m);
    let err = client
        .run_session(&[bad.into_graph()], None, nnscope::client::ExecuteOptions::new())
        .unwrap_err()
        .to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("empty"), "{err}");
}

#[test]
fn dead_grad_skips_backward_but_saved_values_agree() {
    // a grad node nothing consumes: DCE drops it, the backward pass is
    // skipped entirely, and the saved forward values still agree exactly
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 3) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    tr.targets(&[1.0]);
    let _g = tr.grad("layer.0"); // dead
    let h = tr.output("layer.1");
    let m = tr.mean(h);
    tr.save(m);
    let g = tr.graph().clone();
    let eng = Engine::new(&r);
    let raw = eng.run(ExecSpec::raw(&g)).unwrap().result;
    let opt_out = eng.run(ExecSpec::trace(&g)).unwrap();
    let (opt, report) = (opt_out.result, opt_out.report.unwrap());
    assert!(report.dce_removed >= 1);
    assert!(!g.grad_points().is_empty());
    assert_eq!(raw.values, opt.values);
    assert!(!raw.values.is_empty());
}

#[test]
fn random_wire_round_trips_survive_optimization() {
    // serialize → deserialize → optimize → validate: the compiler's
    // output is always a well-formed graph for whatever the wire accepts
    use nnscope::graph::serde as gserde;
    use nnscope::json::parse;
    let r = runner();
    let m = r.manifest.clone();
    let fseq = m.forward_sequence();
    let mut rng = Prng::new(0xAB5);
    for case in 0..20 {
        let g = random_graph(&mut rng, m.seq, m.vocab, m.n_layers);
        let wire = gserde::to_json(&g).to_string();
        let back = gserde::from_json(&parse(&wire).unwrap()).unwrap();
        let o = match opt::optimize(&back, &fseq) {
            Ok(o) => o,
            Err(_) => continue, // admission-rejected graphs are fine
        };
        nnscope::graph::validate::validate(&o.graph, &fseq)
            .unwrap_or_else(|e| panic!("case {case}: optimized graph invalid: {e}"));
    }
}
