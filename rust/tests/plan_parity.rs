//! AOT-plan golden parity suite.
//!
//! The plan layer's contract is that executing through a compiled
//! [`ExecPlan`] — cold or served from the structural cache — is
//! **bit-identical** to the raw interpreter, across every execution
//! shape the server admits: one-shot traces, streaming decode, stateful
//! session bundles, and co-tenant merged forward passes. A cache *hit*
//! additionally skips validation and the optimizer entirely, which is
//! observable (and asserted here) through the admission counters:
//! `plan.hits` rises while `opt.requests` stays flat. Invalid graphs
//! must fail identically whether the plan layer is on, off, or warm —
//! failures are never cached, so a bad graph is rejected afresh every
//! time with the same message.

use std::sync::Arc;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::engine::{Engine, ExecSpec};
use nnscope::graph::opt::Prepared;
use nnscope::graph::plan::{self, PlanMode};
use nnscope::graph::plan_cache::PlanCache;
use nnscope::graph::InterventionGraph;
use nnscope::interp::{self, StateView};
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::scheduler::{execute_merged, execute_merged_prepared};
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::Prng;

fn runner() -> ModelRunner {
    ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap()
}

fn start_server(plan_cache: bool) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.plan_cache = plan_cache;
    NdifServer::start(cfg).unwrap()
}

/// A randomized trace exercising every planner concern: duplicate
/// getters (CSE → template remap), const subtrees (folding → payload
/// rebind), dead chains (DCE + never-materialized arena entries),
/// fusable chains (single-listener slot reuse), setters, and grads
/// (post-phase scheduling).
fn random_graph(rng: &mut Prng, seq: usize, vocab: usize, n_layers: usize) -> InterventionGraph {
    let tokens = Tensor::new(&[1, seq], (0..seq).map(|_| rng.range(0, vocab) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let layer = rng.range(0, n_layers);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    let h_dup = tr.output(&point);
    let c = tr.constant(&Tensor::new(&[4, 4], (0..16).map(|i| (i as f32).cos()).collect()));
    let cs = tr.softmax(c);
    if rng.below(2) == 0 {
        tr.save(cs);
    }
    let _dead = tr.output(&format!("layer.{}", rng.range(0, n_layers)));
    let mut cur = h;
    for _ in 0..rng.range(0, 4) {
        cur = match rng.range(0, 4) {
            0 => {
                let sc = tr.scale(h_dup, 0.25 + rng.uniform_f32());
                tr.add(cur, sc)
            }
            1 => tr.gelu(cur),
            2 => tr.fill(cur, &[Range1::one(0), Range1::one(seq - 1)], rng.uniform_f32()),
            _ => tr.scale(cur, 0.5 + rng.uniform_f32()),
        };
    }
    if rng.below(3) == 0 {
        tr.set_output(&point, cur);
    }
    if rng.below(3) == 0 {
        tr.targets(&[1.0]);
        let g = tr.grad(&format!("layer.{}", rng.range(0, n_layers)));
        let ng = tr.scale(g, -1.0);
        tr.save(ng);
    }
    let later = tr.output(&format!("layer.{}", rng.range(layer, n_layers)));
    let m = tr.mean(later);
    tr.save(m);
    tr.save(cur);
    tr.into_graph()
}

// ---------------------------------------------------------------------------
// Engine-level golden parity: planned (cold and hot) vs raw interpreter
// ---------------------------------------------------------------------------

#[test]
fn planned_traces_match_raw_interpreter_cold_and_hot() {
    let r = runner();
    let m = r.manifest.clone();
    let cache = Arc::new(PlanCache::new(64));
    let planned = Engine::with_plans(&r, Arc::clone(&cache));
    let plain = Engine::new(&r);
    let mut rng = Prng::new(0x9_1A7);
    let mut ok_cases = 0;
    for case in 0..25 {
        let g = random_graph(&mut rng, m.seq, m.vocab, m.n_layers);
        let raw = plain.run(ExecSpec::raw(&g));
        let cold = planned.run(ExecSpec::trace(&g));
        let hot = planned.run(ExecSpec::trace(&g));
        match (raw, cold, hot) {
            (Ok(raw), Ok(cold), Ok(hot)) => {
                ok_cases += 1;
                assert_eq!(
                    raw.result.values, cold.result.values,
                    "case {case}: cold plan diverged from raw interpreter"
                );
                assert_eq!(
                    cold.result.values, hot.result.values,
                    "case {case}: cache hit diverged from cold plan"
                );
                assert!(!raw.result.values.is_empty(), "case {case}: vacuous");
            }
            (Err(_), Err(_), Err(_)) => {} // parity on failure is parity too
            (raw, cold, hot) => panic!(
                "case {case}: raw {:?} / cold {:?} / hot {:?} disagree on success",
                raw.map(|_| ()),
                cold.map(|_| ()),
                hot.map(|_| ())
            ),
        }
    }
    assert!(ok_cases >= 10, "workload almost never executed: {ok_cases}");
    let s = cache.stats();
    assert!(s.hits >= ok_cases, "every second run must hit: {s:?}");
}

#[test]
fn planned_streams_match_raw_cold_and_hot() {
    let r = runner();
    let m = r.manifest.clone();
    let cache = Arc::new(PlanCache::new(16));
    let planned = Engine::with_plans(&r, Arc::clone(&cache));
    let plain = Engine::new(&r);
    let mut rng = Prng::new(0x57_00AB);
    for case in 0..4 {
        let tokens = Tensor::new(
            &[1, m.seq],
            (0..m.seq).map(|_| rng.range(0, m.vocab) as f32).collect(),
        );
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        let c = tr.constant(&Tensor::new(&[4], vec![0.5, -1.0, 2.0, 0.25]));
        let cs = tr.softmax(c);
        let cm = tr.mean(cs);
        tr.step_hook(cm);
        let sc = tr.scale(h, 2.0);
        let sm = tr.softmax(sc);
        let mn = tr.mean(sm);
        tr.step_hook(mn);
        let _dead = tr.output("layer.1");
        let g = tr.into_graph();

        let steps = 3;
        let collect = |eng: &Engine, optimize: bool| {
            let mut events = Vec::new();
            let mut sink = |step: usize, out: interp::StepOutcome| {
                events.push((step, out.token, out.values.values.clone()));
                true
            };
            let spec =
                if optimize { ExecSpec::trace(&g) } else { ExecSpec::raw(&g) }.stream(steps);
            let gen = eng.run_streaming(spec, &mut sink).unwrap().generation.unwrap();
            (events, gen.tokens, gen.scores)
        };
        let raw = collect(&plain, false);
        let cold = collect(&planned, true);
        let hot = collect(&planned, true);
        assert_eq!(raw, cold, "case {case}: cold planned stream diverged from raw");
        assert_eq!(cold, hot, "case {case}: hot planned stream diverged from cold");
    }
    assert!(cache.stats().hits >= 4, "{:?}", cache.stats());
}

#[test]
fn planned_sessions_match_raw_cold_and_hot() {
    let r = runner();
    let m = r.manifest.clone();
    let tokens = Tensor::new(&[1, m.seq], vec![1.0; m.seq]);
    let build = || {
        let mut t0 = Trace::new("tiny-sim", &tokens);
        let h = t0.output("layer.0");
        let flat = t0.mean_axis(h, 0);
        t0.save_to_state("acc", flat);
        let mut t1 = Trace::new("tiny-sim", &tokens);
        let a = t1.from_state("acc");
        let a2 = t1.from_state("acc");
        let sc = t1.scale(a2, 0.5);
        let upd = t1.add(a, sc);
        t1.save_to_state("acc", upd);
        t1.save(upd);
        let mut t2 = Trace::new("tiny-sim", &tokens);
        let a = t2.from_state("acc");
        let mn = t2.mean(a);
        t2.save(mn);
        vec![t0.into_graph(), t1.into_graph(), t2.into_graph()]
    };
    let graphs = build();
    let cache = Arc::new(PlanCache::new(16));
    let planned = Engine::with_plans(&r, Arc::clone(&cache));
    let run = |eng: &Engine, optimize: bool| {
        let mut state = StateView::new();
        let results = eng.run_session(&graphs, &mut state, optimize).unwrap();
        (results, state)
    };
    let (raw_res, raw_state) = run(&Engine::new(&r), false);
    let (cold_res, cold_state) = run(&planned, true);
    let (hot_res, hot_state) = run(&planned, true);
    for (i, (raw, cold)) in raw_res.iter().zip(&cold_res).enumerate() {
        assert_eq!(raw.values, cold.values, "trace {i}: cold planned session diverged");
    }
    for (i, (cold, hot)) in cold_res.iter().zip(&hot_res).enumerate() {
        assert_eq!(cold.values, hot.values, "trace {i}: hot planned session diverged");
    }
    assert!(!raw_res[1].values.is_empty() && !raw_res[2].values.is_empty());
    assert_eq!(raw_state.len(), cold_state.len());
    for (k, v) in &raw_state {
        assert_eq!(v, &cold_state[k], "state key {k} diverged under the cold plan");
        assert_eq!(v, &hot_state[k], "state key {k} diverged under the cache hit");
    }
    let s = cache.stats();
    assert!(s.hits >= 3, "second bundle pass must hit per trace: {s:?}");
}

#[test]
fn planned_cotenant_merge_matches_raw_merge() {
    let r = runner();
    let m = r.manifest.clone();
    let fseq = m.forward_sequence();
    let mut rng = Prng::new(0xC0_7E4A);
    for case in 0..5 {
        let mut graphs = Vec::new();
        for _ in 0..2 {
            let tokens = Tensor::new(
                &[1, m.seq],
                (0..m.seq).map(|_| rng.range(0, m.vocab) as f32).collect(),
            );
            let mut tr = Trace::new("tiny-sim", &tokens);
            for _ in 0..3 {
                let h = tr.output("layer.0");
                let sc = tr.scale(h, 2.0);
                let sm = tr.softmax(sc);
                let mn = tr.mean(sm);
                tr.save(mn);
            }
            graphs.push(tr.into_graph());
        }
        let raw_merged = execute_merged(&graphs, &r).unwrap();
        // the planned side: each co-tenant admitted standalone through the
        // plan compiler, then merged — the batch-group patch happens after
        // bind, exactly as the scheduler does it
        let preps: Vec<Prepared> = graphs
            .iter()
            .map(|g| {
                let p = Arc::new(plan::compile(g, &fseq, PlanMode::Trace, true).unwrap());
                p.bind(g).unwrap()
            })
            .collect();
        let refs: Vec<&Prepared> = preps.iter().collect();
        let plan_merged = execute_merged_prepared(&refs, &r).unwrap();
        for (i, (p, (raw, planned))) in
            preps.iter().zip(raw_merged.iter().zip(plan_merged)).enumerate()
        {
            let raw = raw.as_ref().unwrap();
            let remapped = p.remap_values(planned.unwrap());
            assert_eq!(
                raw.values, remapped.values,
                "case {case} graph {i}: planned merge diverged from raw merge"
            );
            assert!(!raw.values.is_empty());
        }
    }
}

// ---------------------------------------------------------------------------
// Server-level admission behavior
// ---------------------------------------------------------------------------

fn probe_trace(tokens: &Tensor) -> (Trace, nnscope::client::SavedRef) {
    let mut tr = Trace::new("tiny-sim", tokens);
    let h = tr.output("layer.0");
    let h2 = tr.output("layer.0");
    let sc = tr.scale(h2, 2.0);
    let sm = tr.softmax(sc);
    let mn = tr.mean(sm);
    let s = tr.save(mn);
    let mn2 = tr.mean(h);
    tr.save(mn2);
    let _dead = tr.gelu(h);
    (tr, s)
}

/// The acceptance-criteria assertion: a cache hit must skip validation
/// and the optimizer entirely. `opt.requests` counts admissions that ran
/// the compiler, `plan.hits`/`plan.misses` count cache outcomes — after
/// two structurally identical submissions the compiler must have run
/// exactly once.
#[test]
fn cache_hit_skips_validate_and_opt_counters() {
    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let tokens_a = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
    let tokens_b = Tensor::new(&[1, 16], (0..16).map(|i| (i % 5) as f32).collect());

    let (tr, _) = probe_trace(&tokens_a);
    tr.run_remote(&client).unwrap();
    let m = client.metrics().unwrap();
    let tm = m.get("tiny-sim");
    assert_eq!(tm.get("plan").get("misses").as_i64(), Some(1));
    assert_eq!(tm.get("plan").get("hits").as_i64(), Some(0));
    assert_eq!(tm.get("opt").get("requests").as_i64(), Some(1));

    // same structure, different tokens: must hit, and the optimizer must
    // NOT run again
    let (tr, _) = probe_trace(&tokens_b);
    tr.run_remote(&client).unwrap();
    let m = client.metrics().unwrap();
    let tm = m.get("tiny-sim");
    assert_eq!(tm.get("plan").get("hits").as_i64(), Some(1), "{m}");
    assert_eq!(tm.get("plan").get("misses").as_i64(), Some(1), "{m}");
    assert_eq!(
        tm.get("opt").get("requests").as_i64(),
        Some(1),
        "opt must stay flat on a plan-cache hit: {m}"
    );

    // the global _plan gauges agree with the per-model counters
    let p = m.get("_plan");
    assert_eq!(p.get("enabled").as_bool(), Some(true));
    assert_eq!(p.get("hits").as_i64(), Some(1));
    assert_eq!(p.get("misses").as_i64(), Some(1));
    assert_eq!(p.get("size").as_i64(), Some(1));
    assert!(p.get("slots_planned").as_i64().unwrap_or(0) >= 1);
}

#[test]
fn no_plan_cache_flag_restores_legacy_admission_with_identical_values() {
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());

    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let (tr, s) = probe_trace(&tokens);
    let planned_value = tr.run_remote(&client).unwrap().get(s).clone();
    drop(server);

    let server = start_server(false);
    let client = NdifClient::new(server.addr());
    let (tr, s2) = probe_trace(&tokens);
    let res = tr.run_remote(&client).unwrap();
    assert_eq!(
        &planned_value,
        res.get(s2),
        "values must not depend on the plan cache"
    );
    let m = client.metrics().unwrap();
    assert_eq!(m.get("_plan").get("enabled").as_bool(), Some(false));
    assert_eq!(m.get("_obs").get("plan_cache").as_bool(), Some(false));
    // with the cache off the legacy path still counts the optimizer
    assert_eq!(m.get("tiny-sim").get("opt").get("requests").as_i64(), Some(1));
}

#[test]
fn invalid_graphs_fail_identically_cold_warm_and_unplanned() {
    let bad = |client: &NdifClient| {
        let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);
        let mut tr = Trace::new("tiny-sim", &tokens);
        let c = tr.constant(&Tensor::new(&[4], vec![1.0; 4]));
        let empty = tr.slice(c, &[Range1::new(2, 2)]);
        let m = tr.mean(empty);
        tr.save(m);
        tr.run_remote(client).unwrap_err().to_string()
    };
    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let cold = bad(&client);
    // failures are never cached: resubmitting must reject again, with the
    // same admission 400 — not execute a half-built plan
    let warm = bad(&client);
    assert!(cold.contains("400"), "{cold}");
    assert!(cold.contains("empty"), "{cold}");
    assert_eq!(cold, warm, "a failed compile must not change behavior when resubmitted");
    let m = client.metrics().unwrap();
    assert_eq!(m.get("_plan").get("size").as_i64(), Some(0), "failures must not be cached");
    drop(server);

    let server = start_server(false);
    let unplanned = bad(&NdifClient::new(server.addr()));
    assert_eq!(cold, unplanned, "rejection must not depend on the plan layer");
}

#[test]
fn stream_and_session_endpoints_hit_the_plan_cache() {
    use nnscope::client::remote::StreamEvent;
    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 5) as f32).collect());

    let build_stream = || {
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        let sc = tr.scale(h, 3.0);
        let sm = tr.softmax(sc);
        let mn = tr.mean(sm);
        tr.step_hook(mn);
        tr
    };
    let collect = || {
        let mut steps = Vec::new();
        for ev in build_stream().run_stream(&client, 3).unwrap() {
            match ev.unwrap() {
                StreamEvent::Step { step, token, values, .. } => {
                    steps.push((step, token, values.values))
                }
                StreamEvent::Done { tokens, .. } => assert_eq!(tokens.len(), 3),
            }
        }
        steps
    };
    let cold = collect();
    let hot = collect();
    assert_eq!(cold, hot, "streamed values must not depend on plan-cache temperature");

    let run_session = || {
        let mut t0 = Trace::new("tiny-sim", &tokens);
        let c = t0.constant(&Tensor::scalar(2.0));
        let c2 = t0.constant(&Tensor::scalar(3.0));
        let folded = t0.mul(c, c2);
        t0.save_to_state("acc", folded);
        let mut t1 = Trace::new("tiny-sim", &tokens);
        let a = t1.from_state("acc");
        t1.save(a);
        client
            .run_session(
                &[t0.into_graph(), t1.into_graph()],
                None,
                nnscope::client::ExecuteOptions::new(),
            )
            .unwrap()
    };
    let cold = run_session();
    let hot = run_session();
    assert_eq!(cold[1].values, hot[1].values);
    assert_eq!(cold[1].values.values().next().unwrap().item(), 6.0);

    let m = client.metrics().unwrap();
    let p = m.get("tiny-sim").get("plan");
    // stream hit once, both session traces hit once each
    assert_eq!(p.get("hits").as_i64(), Some(3), "{m}");
    assert_eq!(p.get("misses").as_i64(), Some(3), "{m}");
}

// ---------------------------------------------------------------------------
// Invalidation regressions: model swap and config change are keyed, not TTL
// ---------------------------------------------------------------------------

/// A stale plan for a reloaded model must never execute: the reload path
/// calls [`NdifServer::invalidate_plans`], which evicts that model's
/// plans by key while other tenants' plans survive.
#[test]
fn model_swap_invalidates_cached_plans() {
    let server = start_server(true);
    let client = NdifClient::new(server.addr());
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());

    let (tr, _) = probe_trace(&tokens);
    tr.run_remote(&client).unwrap();
    assert_eq!(client.metrics().unwrap().get("_plan").get("size").as_i64(), Some(1));

    let evicted = server.invalidate_plans("tiny-sim");
    assert_eq!(evicted, 1, "the cached plan must be evicted on model swap");
    assert_eq!(server.invalidate_plans("tiny-sim"), 0, "idempotent");

    // next structurally identical submission recompiles — a miss, and the
    // optimizer runs again
    let (tr, _) = probe_trace(&tokens);
    tr.run_remote(&client).unwrap();
    let m = client.metrics().unwrap();
    let tm = m.get("tiny-sim");
    assert_eq!(tm.get("plan").get("hits").as_i64(), Some(0), "{m}");
    assert_eq!(tm.get("plan").get("misses").as_i64(), Some(2), "{m}");
    assert_eq!(tm.get("opt").get("requests").as_i64(), Some(2), "{m}");
    assert!(m.get("_plan").get("invalidations").as_i64().unwrap_or(0) >= 1);
}

/// The optimizer flag is part of the structural key: a `--no-opt` config
/// change can never be served a stale optimized plan (keyed miss, not a
/// TTL race).
#[test]
fn optimize_flag_is_part_of_the_plan_key() {
    let r = runner();
    let m = r.manifest.clone();
    let tokens = Tensor::new(&[1, m.seq], (0..m.seq).map(|i| (i % 3) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let h2 = tr.output("layer.0");
    let sc = tr.scale(h2, 2.0);
    let mn = tr.mean(sc);
    tr.save(mn);
    let mn2 = tr.mean(h);
    tr.save(mn2);
    let g = tr.into_graph();

    assert_ne!(
        plan::structural_key(&g, PlanMode::Trace, true),
        plan::structural_key(&g, PlanMode::Trace, false),
        "optimize flag must partition the key space"
    );
    // and mode partitions it too: the three admission paths validate
    // different rule sets, so their plans must never cross
    assert_ne!(
        plan::structural_key(&g, PlanMode::Trace, true),
        plan::structural_key(&g, PlanMode::Stream, true),
    );

    let cache = Arc::new(PlanCache::new(8));
    let eng = Engine::with_plans(&r, Arc::clone(&cache));
    let opt_out = eng.run(ExecSpec::trace(&g)).unwrap();
    let raw_out = eng.run(ExecSpec::raw(&g)).unwrap();
    let s = cache.stats();
    assert_eq!(s.misses, 2, "config change must compile a fresh plan: {s:?}");
    assert_eq!(s.hits, 0, "{s:?}");
    assert_eq!(
        opt_out.result.values, raw_out.result.values,
        "values must not depend on which plan ran"
    );
    assert!(opt_out.report.is_some() && raw_out.report.is_none());
}
