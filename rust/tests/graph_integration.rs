//! End-to-end intervention-graph experiments against real compiled
//! artifacts: the paper's §3.2 use cases (activation patching, ablation,
//! logit lens, gradient access) expressed through the tracing API and
//! executed by the interpreter over the PJRT runtime.

use nnscope::client::Trace;
use nnscope::models::{artifacts_dir, workload::IoiBatch, ModelRunner};
use nnscope::tensor::{Range1, Tensor};

fn runner() -> ModelRunner {
    ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap()
}

#[test]
fn trace_save_equals_plain_forward() {
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let logits = tr.output("lm_head");
    let s = tr.save(logits);
    let res = tr.run_local(&r).unwrap();
    let direct = r.forward_plain(&tokens).unwrap();
    assert!(res.get(s).allclose(&direct, 1e-6));
}

#[test]
fn activation_patching_changes_logit_diff() {
    // IOI-style activation patching: run source+base in one batch, copy
    // the source row's hidden state at a layer into the base row, and
    // measure target-vs-foil logit difference on the base row.
    let r = runner();
    let m = r.manifest.clone();
    let batch = IoiBatch::generate(2, m.vocab, m.seq, 99);
    let e = batch.examples[0].clone();
    let tokens = Tensor::new(
        &[2, m.seq],
        e.source.iter().chain(e.base.iter()).copied().collect(),
    );

    // unpatched logit diff on the base row
    let mut tr = Trace::new("tiny-sim", &tokens);
    let logits = tr.output("lm_head");
    let base_row = tr.slice(logits, &[Range1::new(1, 2)]);
    let ld = tr.logit_diff(base_row, e.target, e.foil);
    let s = tr.save(ld);
    let base_ld = tr.run_local(&r).unwrap().get(s).data()[0];

    // patched: copy source-row layer.0 output (last token) into base row
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let src = tr.slice(h, &[Range1::new(0, 1), Range1::one(m.seq - 1)]);
    let patched = tr.assign(h, &[Range1::new(1, 2), Range1::one(m.seq - 1)], src);
    tr.set_output("layer.0", patched);
    let logits = tr.output("lm_head");
    let base_row = tr.slice(logits, &[Range1::new(1, 2)]);
    let ld = tr.logit_diff(base_row, e.target, e.foil);
    let s = tr.save(ld);
    let patched_ld = tr.run_local(&r).unwrap().get(s).data()[0];

    assert_ne!(base_ld, patched_ld, "patching had no effect");
}

#[test]
fn neuron_ablation_changes_output() {
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i * 3 % 11) as f32).collect());

    let plain = r.forward_plain(&tokens).unwrap();

    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    // zero neurons 0..8 at the last token (the Fig. 3 style intervention)
    let ablated = tr.fill(h, &[Range1::one(0), Range1::one(15), Range1::new(0, 8)], 0.0);
    tr.set_output("layer.0", ablated);
    let logits = tr.output("lm_head");
    let s = tr.save(logits);
    let res = tr.run_local(&r).unwrap();
    assert!(!res.get(s).allclose(&plain, 1e-6));
}

#[test]
fn logit_lens_midlayer_decode() {
    // read layer.0 hidden state, decode through the unembedding weights
    // shipped as a constant — arbitrary user compute on intermediates.
    let r = runner();
    let m = r.manifest.clone();
    let wout = r.weights.modules["lm_head"][2].clone(); // [d, vocab]
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 5) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let last = tr.slice(h, &[Range1::one(0), Range1::one(m.seq - 1)]);
    let w = tr.constant(&wout);
    let lens_logits = tr.matmul(last, w);
    let am = tr.argmax(lens_logits);
    let s = tr.save(am);
    let res = tr.run_local(&r).unwrap();
    let v = res.get(s);
    assert_eq!(v.numel(), 1);
    assert!(v.data()[0] >= 0.0 && (v.data()[0] as usize) < m.vocab);
}

#[test]
fn grad_via_trace_matches_backward() {
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    tr.targets(&[3.0]);
    let g = tr.grad("layer.0");
    let s = tr.save(g);
    let res = tr.run_local(&r).unwrap();
    let got = res.get(s);

    let (_, grads) = r
        .backward(&tokens, &Tensor::new(&[1], vec![3.0]), &["layer.0".to_string()])
        .unwrap();
    assert!(got.allclose(&grads["layer.0"], 1e-6));
}

#[test]
fn attribution_patching_style_grad_dot_activation() {
    // attribution patching ≈ (h_src - h_base) · ∂L/∂h — needs both a
    // getter and a grad at the same module in one trace.
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| ((i * 2) % 9) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    tr.targets(&[1.0]);
    let h = tr.output("layer.1");
    let g = tr.grad("layer.1");
    let prod = tr.mul(h, g);
    let attr = tr.sum(prod);
    let s = tr.save(attr);
    let res = tr.run_local(&r).unwrap();
    assert!(res.get(s).item().is_finite());
}

#[test]
fn sharded_trace_matches_unsharded() {
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let logits = tr.output("lm_head");
    let s = tr.save(logits);
    let base = tr.run_local(&r).unwrap();

    let mut tr = Trace::new("tiny-sim", &tokens);
    tr.shards(2);
    let logits = tr.output("lm_head");
    let s2 = tr.save(logits);
    let sharded = tr.run_local(&r).unwrap();

    assert!(
        base.get(s).allclose(sharded.get(s2), 5e-4),
        "diff {}",
        base.get(s).max_abs_diff(sharded.get(s2))
    );
}

#[test]
fn invalid_graph_rejected_before_execution() {
    let r = runner();
    let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);
    // acyclicity violation: logits written into layer.0
    let mut tr = Trace::new("tiny-sim", &tokens);
    let logits = tr.output("lm_head");
    tr.set_output("layer.0", logits);
    assert!(tr.run_local(&r).is_err());
}

#[test]
fn session_runs_traces_in_order() {
    use nnscope::client::Session;
    let r = runner();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 3) as f32).collect());
    let mut session = Session::new();

    let mut t1 = Trace::new("tiny-sim", &tokens);
    let h = t1.output("layer.0");
    let s1 = t1.save(h);
    session.add(t1);

    let mut t2 = Trace::new("tiny-sim", &tokens);
    let l = t2.output("lm_head");
    let s2 = t2.save(l);
    session.add(t2);

    let results = session.run_local(&r).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].get(s1).dims(), &[1, 16, 32]);
    assert_eq!(results[1].get(s2).dims(), &[1, 16, 64]);
}
