//! Integration: the python-AOT → rust-PJRT bridge, end to end.
//!
//! `python/compile/aot.py` exports `artifacts/tiny-sim/check.json` with
//! reference logits computed in pure JAX from the shared deterministic
//! weights. These tests regenerate the weights in Rust, execute the
//! compiled module sequence through PJRT, and assert the numbers match —
//! proving the weight contract, the HLO-text interchange, and the runner's
//! interleaving semantics all at once.

use nnscope::json::parse;
use nnscope::models::{artifacts_dir, Hooks, ModelRunner};
use nnscope::tensor::{Range1, Tensor};

fn check_json() -> nnscope::json::Json {
    let path = artifacts_dir().join("tiny-sim/check.json");
    let text = std::fs::read_to_string(path).expect("check.json (run `make artifacts`)");
    parse(&text).unwrap()
}

fn runner() -> ModelRunner {
    ModelRunner::load(&artifacts_dir(), "tiny-sim").expect("load tiny-sim")
}

fn tokens_from_check(check: &nnscope::json::Json, seq: usize) -> Tensor {
    let toks: Vec<f32> = check
        .get("tokens")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let b = check.get("batch").as_usize().unwrap();
    Tensor::new(&[b, seq], toks)
}

#[test]
fn forward_matches_python_reference() {
    let r = runner();
    let check = check_json();
    let tol = check.get("tol").as_f64().unwrap() as f32;
    let tokens = tokens_from_check(&check, r.manifest.seq);

    let logits = r.forward_plain(&tokens).unwrap();
    assert_eq!(logits.dims(), &[1, r.manifest.seq, r.manifest.vocab]);

    let expect: Vec<f32> = check
        .get("logits_sample")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let last = logits.slice(&[
        Range1::one(0),
        Range1::one(r.manifest.seq - 1),
        Range1::new(0, 8),
    ]);
    for (i, (&got, &want)) in last.data().iter().zip(&expect).enumerate() {
        assert!(
            (got - want).abs() <= tol,
            "logit {i}: rust={got} python={want} (tol {tol})"
        );
    }
    let norm = logits.norm();
    let expect_norm = check.get("logits_norm").as_f64().unwrap() as f32;
    assert!(
        (norm - expect_norm).abs() / expect_norm < 1e-3,
        "norm {norm} vs {expect_norm}"
    );
}

#[test]
fn hook_observes_python_reference_hidden_state() {
    let r = runner();
    let check = check_json();
    let tol = check.get("tol").as_f64().unwrap() as f32;
    let tokens = tokens_from_check(&check, r.manifest.seq);

    struct Capture {
        seen: Option<Tensor>,
    }
    impl Hooks for Capture {
        fn wants(&self, point: &str) -> bool {
            point == "layer.0"
        }
        fn on_output(&mut self, _p: &str, t: &mut Tensor) -> bool {
            self.seen = Some(t.clone());
            false
        }
    }
    let mut cap = Capture { seen: None };
    r.forward(&tokens, &mut cap).unwrap();
    let h = cap.seen.expect("hook fired");
    let expect: Vec<f32> = check
        .get("hidden_l0_sample")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let got = h.slice(&[
        Range1::one(0),
        Range1::one(r.manifest.seq - 1),
        Range1::new(0, 8),
    ]);
    for (i, (&g, &w)) in got.data().iter().zip(&expect).enumerate() {
        assert!((g - w).abs() <= tol, "hidden {i}: {g} vs {w}");
    }
}

#[test]
fn setter_hook_reproduces_python_patched_logits() {
    let r = runner();
    let check = check_json();
    let tol = check.get("tol").as_f64().unwrap() as f32;
    let tokens = tokens_from_check(&check, r.manifest.seq);
    let seq = r.manifest.seq;
    let d = r.manifest.d_model;

    struct Patch {
        seq: usize,
        d: usize,
    }
    impl Hooks for Patch {
        fn wants(&self, point: &str) -> bool {
            point == "layer.0"
        }
        fn on_output(&mut self, _p: &str, t: &mut Tensor) -> bool {
            t.slice_assign(
                &[Range1::one(0), Range1::one(self.seq - 1)],
                &Tensor::full(&[1, 1, self.d], 1.0),
            );
            true
        }
    }
    let logits = r.forward(&tokens, &mut Patch { seq, d }).unwrap();
    let expect: Vec<f32> = check
        .get("patched_logits_sample")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let got = logits.slice(&[Range1::one(0), Range1::one(seq - 1), Range1::new(0, 8)]);
    for (i, (&g, &w)) in got.data().iter().zip(&expect).enumerate() {
        assert!((g - w).abs() <= tol, "patched logit {i}: {g} vs {w}");
    }
}

#[test]
fn sharded_forward_matches_unsharded() {
    let r = runner();
    let check = check_json();
    let tokens = tokens_from_check(&check, r.manifest.seq);
    let base = r.forward_plain(&tokens).unwrap();
    let sharded = r
        .forward_sharded(&tokens, 2, &mut nnscope::models::NoHooks)
        .unwrap();
    assert!(
        base.allclose(&sharded, 5e-4),
        "tp=2 max diff {}",
        base.max_abs_diff(&sharded)
    );
}

#[test]
fn sharded_rejects_unexported_shard_count() {
    let r = runner();
    let tokens = Tensor::zeros(&[1, r.manifest.seq]);
    assert!(r
        .forward_sharded(&tokens, 3, &mut nnscope::models::NoHooks)
        .is_err());
}

#[test]
fn backward_produces_finite_grads_that_decrease_loss() {
    let r = runner();
    let seq = r.manifest.seq;
    let tokens = Tensor::new(&[1, seq], (0..seq).map(|i| (i % 7) as f32).collect());
    let targets = Tensor::new(&[1], vec![3.0]);
    let points = vec!["layer.0".to_string(), "layer.1".to_string()];
    let (loss, grads) = r.backward(&tokens, &targets, &points).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_eq!(grads.len(), 2);
    for (p, g) in &grads {
        assert_eq!(g.dims(), &[1, seq, r.manifest.d_model], "{p}");
        assert!(g.data().iter().all(|v| v.is_finite()), "{p}");
        assert!(g.norm() > 0.0, "{p} grad is zero");
    }

    // gradient sanity: perturbing the layer.1 output against the gradient
    // direction must reduce the loss (first-order).
    let g1 = &grads["layer.1"];
    let eps = 0.05 / g1.norm();
    struct Nudge<'a> {
        g: &'a Tensor,
        eps: f32,
    }
    impl Hooks for Nudge<'_> {
        fn wants(&self, p: &str) -> bool {
            p == "layer.1"
        }
        fn on_output(&mut self, _p: &str, t: &mut Tensor) -> bool {
            let stepped = t.sub(&self.g.scale(self.eps));
            *t = stepped;
            true
        }
    }
    // recompute loss via lm_head_grad on nudged hidden state: use backward's
    // loss with a hooked forward is not directly exposed; instead compare
    // logit of target before/after nudging through plain forward + manual CE.
    let base_logits = r.forward_plain(&tokens).unwrap();
    let nudged_logits = r.forward(&tokens, &mut Nudge { g: g1, eps }).unwrap();
    let ce = |logits: &Tensor| -> f32 {
        let last = logits.slice(&[Range1::one(0), Range1::one(seq - 1)]);
        let flat = last.clone().reshape(&[r.manifest.vocab]);
        let sm = flat.softmax_last();
        -(sm.data()[3].ln())
    };
    assert!(
        ce(&nudged_logits) < ce(&base_logits),
        "nudge against grad should reduce CE: {} !< {}",
        ce(&nudged_logits),
        ce(&base_logits)
    );
}

#[test]
fn pad_tokens_rounds_up_to_exported_batch() {
    let r = runner();
    let t = Tensor::zeros(&[3, r.manifest.seq]);
    let (padded, n) = r.pad_tokens(&t).unwrap();
    assert_eq!(n, 3);
    assert_eq!(padded.dims()[0], 4); // tiny-sim exports b in {1,4}
    let too_big = Tensor::zeros(&[5, r.manifest.seq]);
    assert!(r.pad_tokens(&too_big).is_err());
}
