//! Property tests over the full stack: randomly generated intervention
//! graphs must (a) round-trip the wire format, (b) agree between scan's
//! predicted shapes and executed shapes, (c) never corrupt co-tenant
//! neighbours, and (d) never crash the server even when mangled.
//!
//! The second half of this file holds the kernel oracle-parity tests:
//! every optimized tensor kernel is compared against the retained seed
//! implementation (`nnscope::tensor::ops::naive`) across randomized
//! shapes — broadcast rank mismatches and size-1 dims, non-contiguous and
//! empty slices, and sizes on both sides of the parallel-dispatch
//! cutoffs. Elementwise/slicing kernels must match exactly; matmul (a
//! reassociated reduction) within 1e-4.

use nnscope::client::Trace;
use nnscope::graph::serde as gserde;
use nnscope::json::parse;
use nnscope::models::{artifacts_dir, Hooks, ModelRunner};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::Prng;

/// Build a random-but-valid trace over tiny-sim.
fn random_trace(rng: &mut Prng, seq: usize, vocab: usize, n_layers: usize) -> Trace {
    let batch = rng.range(1, 3); // 1 or 2 rows (exported batches 1,4)
    let tokens = Tensor::new(
        &[batch, seq],
        (0..batch * seq).map(|_| rng.range(0, vocab) as f32).collect(),
    );
    let mut tr = Trace::new("tiny-sim", &tokens);
    let layer = rng.range(0, n_layers);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    // a random chain of shape-preserving ops
    let mut cur = h;
    for _ in 0..rng.range(0, 4) {
        cur = match rng.range(0, 4) {
            0 => tr.scale(cur, 0.5 + rng.uniform_f32()),
            1 => tr.gelu(cur),
            2 => tr.add(cur, h),
            _ => {
                let f = rng.uniform_f32();
                tr.fill(cur, &[Range1::one(0), Range1::one(seq - 1)], f)
            }
        };
    }
    // maybe write it back (valid: same module)
    if rng.below(2) == 0 {
        tr.set_output(&point, cur);
    }
    // read somewhere downstream and reduce
    let later = rng.range(layer, n_layers);
    let h2 = tr.output(&format!("layer.{later}"));
    let m = tr.mean(h2);
    tr.save(m);
    tr.save(cur);
    tr
}

#[test]
fn random_graphs_scan_execute_and_round_trip() {
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    let mut rng = Prng::new(0x5EED);
    for case in 0..25 {
        let tr = random_trace(&mut rng, m.seq, m.vocab, m.n_layers);
        // wire round trip preserves the graph
        let g = tr.graph().clone();
        let wire = gserde::to_json(&g).to_string();
        let back = gserde::from_json(&parse(&wire).unwrap()).unwrap();
        assert_eq!(back.nodes, g.nodes, "case {case}");

        // scan's shapes match executed shapes for every save
        let shapes = tr.scan(&m).unwrap_or_else(|e| panic!("case {case}: scan {e}"));
        let res = tr
            .run_local(&runner)
            .unwrap_or_else(|e| panic!("case {case}: exec {e}"));
        for (id, t) in &res.inner().values {
            // the save node's shape equals its dependency's shape
            assert_eq!(
                t.dims(),
                &shapes[*id][..],
                "case {case}: node {id} shape mismatch"
            );
            assert!(t.data().iter().all(|v| v.is_finite()), "case {case}");
        }
    }
}

#[test]
fn random_cotenant_merges_preserve_solo_results() {
    use nnscope::scheduler::execute_merged;
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    let mut rng = Prng::new(0xC0C0);
    for case in 0..10 {
        // two single-row graphs (fit in batch 4 together)
        let mut graphs = Vec::new();
        for _ in 0..2 {
            let tokens = Tensor::new(
                &[1, m.seq],
                (0..m.seq).map(|_| rng.range(0, m.vocab) as f32).collect(),
            );
            let mut tr = Trace::new("tiny-sim", &tokens);
            let layer = rng.range(0, m.n_layers);
            let point = format!("layer.{layer}");
            let h = tr.output(&point);
            if rng.below(2) == 0 {
                let z = tr.scale(h, rng.uniform_f32());
                tr.set_output(&point, z);
            }
            let logits = tr.output("lm_head");
            tr.save(logits);
            graphs.push(tr.into_graph());
        }
        let solo: Vec<_> = graphs
            .iter()
            .map(|g| nnscope::interp::execute(g, &runner).unwrap())
            .collect();
        let merged = execute_merged(&graphs, &runner).unwrap();
        for (i, (s, mr)) in solo.iter().zip(&merged).enumerate() {
            let mr = mr.as_ref().unwrap();
            for (id, t) in &s.values {
                assert!(
                    mr.values[id].allclose(t, 1e-4),
                    "case {case} graph {i} node {id}: diff {}",
                    mr.values[id].max_abs_diff(t)
                );
            }
        }
    }
}

#[test]
fn mangled_requests_never_crash_the_server() {
    use nnscope::server::{http, NdifConfig, NdifServer};
    let server = NdifServer::start(NdifConfig::local(&["tiny-sim"])).unwrap();
    let addr = server.addr();

    // a valid request to mutate
    let runner_manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), "tiny-sim").unwrap();
    let tokens = Tensor::zeros(&[1, runner_manifest.seq]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let valid = gserde::to_json(tr.graph()).to_string();

    let mut rng = Prng::new(0xFA22);
    for _ in 0..40 {
        let mut bytes = valid.clone().into_bytes();
        match rng.range(0, 4) {
            0 => {
                // truncate
                let cut = rng.range(0, bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                // flip a byte
                let i = rng.range(0, bytes.len());
                bytes[i] = bytes[i].wrapping_add(rng.below(255) as u8 + 1);
            }
            2 => {
                // duplicate a chunk
                let i = rng.range(0, bytes.len());
                let chunk: Vec<u8> = bytes[i..].to_vec();
                bytes.extend_from_slice(&chunk);
            }
            _ => {
                // random garbage
                bytes = (0..rng.range(1, 200)).map(|_| rng.below(256) as u8).collect();
            }
        }
        // must answer (with any status), not hang or die
        let (status, _) = http::post(addr, "/v1/trace", &bytes).expect("server alive");
        assert!(status == 202 || status == 400 || status == 404 || status == 401);
    }

    // the server still works after the fuzzing
    let (status, _) = http::get(addr, "/health").unwrap();
    assert_eq!(status, 200);
    let client = nnscope::client::remote::NdifClient::new(addr);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let s = tr.save(h);
    let res = tr.run_remote(&client).unwrap();
    assert_eq!(res.get(s).dims(), &[1, 16, 32]);
}

// ---------------------------------------------------------------------------
// Kernel oracle parity
// ---------------------------------------------------------------------------

use nnscope::tensor::ops::naive;

/// Random dims, each in `[1, 6)`, rank in `[1, max_rank]`.
fn rand_dims(rng: &mut Prng, max_rank: usize) -> Vec<usize> {
    let rank = rng.range(1, max_rank + 1);
    (0..rank).map(|_| rng.range(1, 6)).collect()
}

/// Derive a broadcast-compatible operand shape from `base`: drop a random
/// number of leading dims (rank mismatch), then squash random surviving
/// dims to size 1 (expansion).
fn rand_broadcast_operand(rng: &mut Prng, base: &[usize]) -> Vec<usize> {
    let drop = rng.range(0, base.len() + 1);
    base[drop..]
        .iter()
        .map(|&d| if rng.below(3) == 0 { 1 } else { d })
        .collect()
}

fn rand_tensor(rng: &mut Prng, dims: &[usize]) -> Tensor {
    Tensor::from_randn(dims, rng, 1.0)
}

/// Random clamped ranges over a prefix of `dims`, with whole, partial,
/// point, and empty ranges all represented.
fn rand_ranges(rng: &mut Prng, dims: &[usize]) -> Vec<Range1> {
    let prefix = rng.range(0, dims.len() + 1);
    dims[..prefix]
        .iter()
        .map(|&d| match rng.below(4) {
            0 => Range1::all(),
            1 => {
                let s = rng.range(0, d);
                Range1::one(s)
            }
            2 => {
                let s = rng.range(0, d + 1);
                Range1::new(s, s) // empty
            }
            _ => {
                let s = rng.range(0, d);
                let e = rng.range(s + 1, d + 1);
                Range1::new(s, e)
            }
        })
        .collect()
}

#[test]
fn broadcast_binop_matches_naive_across_random_shapes() {
    let mut rng = Prng::new(0xB40C);
    for case in 0..200 {
        let base = rand_dims(&mut rng, 4);
        let a = rand_tensor(&mut rng, &rand_broadcast_operand(&mut rng, &base));
        let b = rand_tensor(&mut rng, &rand_broadcast_operand(&mut rng, &base));
        assert_eq!(a.add(&b), naive::binop(&a, &b, |x, y| x + y), "case {case}: add");
        assert_eq!(a.mul(&b), naive::binop(&a, &b, |x, y| x * y), "case {case}: mul");
        assert_eq!(a.sub(&b), naive::binop(&a, &b, |x, y| x - y), "case {case}: sub");
    }
}

#[test]
fn slice_matches_naive_including_noncontiguous_and_empty() {
    let mut rng = Prng::new(0x511CE);
    for case in 0..200 {
        let dims = rand_dims(&mut rng, 4);
        let t = rand_tensor(&mut rng, &dims);
        let ranges = rand_ranges(&mut rng, &dims);
        assert_eq!(t.slice(&ranges), naive::slice(&t, &ranges), "case {case}: {ranges:?}");
    }
}

#[test]
fn slice_assign_matches_naive() {
    let mut rng = Prng::new(0xA551);
    for case in 0..200 {
        let dims = rand_dims(&mut rng, 4);
        let t = rand_tensor(&mut rng, &dims);
        let ranges = rand_ranges(&mut rng, &dims);
        let src = rand_tensor(&mut rng, naive::slice(&t, &ranges).dims());
        let mut got = t.clone();
        got.slice_assign(&ranges, &src);
        let mut want = t.clone();
        naive::slice_assign(&mut want, &ranges, &src);
        assert_eq!(got, want, "case {case}: {ranges:?}");
    }
}

#[test]
fn slice_fill_matches_assign_of_constant() {
    let mut rng = Prng::new(0xF111);
    for case in 0..200 {
        let dims = rand_dims(&mut rng, 4);
        let t = rand_tensor(&mut rng, &dims);
        let ranges = rand_ranges(&mut rng, &dims);
        let v = rng.uniform_f32();
        let mut got = t.clone();
        got.slice_fill(&ranges, v);
        let mut want = t.clone();
        let patch = Tensor::full(naive::slice(&t, &ranges).dims(), v);
        naive::slice_assign(&mut want, &ranges, &patch);
        assert_eq!(got, want, "case {case}: {ranges:?}");
    }
}

#[test]
fn index_select_matches_naive_with_repeats() {
    let mut rng = Prng::new(0x1D5E);
    for case in 0..200 {
        let dims = rand_dims(&mut rng, 4);
        let t = rand_tensor(&mut rng, &dims);
        let axis = rng.range(0, dims.len());
        let n = rng.range(1, 7);
        let indices: Vec<usize> = (0..n).map(|_| rng.range(0, dims[axis])).collect();
        assert_eq!(
            t.index_select(axis, &indices),
            naive::index_select(&t, axis, &indices),
            "case {case}: axis {axis} indices {indices:?}"
        );
    }
}

#[test]
fn mean_axis_matches_naive_bit_exact() {
    let mut rng = Prng::new(0x3EA4);
    for case in 0..200 {
        let dims = rand_dims(&mut rng, 4);
        let t = rand_tensor(&mut rng, &dims);
        let axis = rng.range(0, dims.len());
        assert_eq!(t.mean_axis(axis), naive::mean_axis(&t, axis), "case {case}: axis {axis}");
    }
}

#[test]
fn concat_matches_naive() {
    let mut rng = Prng::new(0xC04C);
    for case in 0..100 {
        let mut dims = rand_dims(&mut rng, 3);
        let axis = rng.range(0, dims.len());
        let parts: Vec<Tensor> = (0..rng.range(1, 5))
            .map(|_| {
                dims[axis] = rng.range(1, 6);
                rand_tensor(&mut rng, &dims)
            })
            .collect();
        let refs: Vec<&Tensor> = parts.iter().collect();
        assert_eq!(
            Tensor::concat(&refs, axis),
            naive::concat(&refs, axis),
            "case {case}: axis {axis}"
        );
    }
}

#[test]
fn matmul_matches_naive_within_reassociation_tolerance() {
    let mut rng = Prng::new(0x3A73);
    // small/odd shapes stay on the sequential path; the last cases cross
    // the parallel cutoff (m·k·n ≥ 2^18)
    for case in 0..60 {
        let (m, k, n) = if case < 50 {
            (rng.range(1, 40), rng.range(1, 40), rng.range(1, 40))
        } else {
            (rng.range(64, 100), rng.range(64, 100), rng.range(64, 100))
        };
        let a = rand_tensor(&mut rng, &[m, k]);
        let b = rand_tensor(&mut rng, &[k, n]);
        let got = a.matmul(&b);
        let want = naive::matmul(&a, &b);
        assert!(
            got.allclose(&want, 1e-4),
            "case {case}: {m}x{k}x{n} diff {}",
            got.max_abs_diff(&want)
        );
    }
    // batched N-D × 2-D
    for case in 0..20 {
        let (b1, b2, k, n) =
            (rng.range(1, 5), rng.range(1, 6), rng.range(1, 30), rng.range(1, 30));
        let a = rand_tensor(&mut rng, &[b1, b2, k]);
        let b = rand_tensor(&mut rng, &[k, n]);
        let got = a.matmul(&b);
        let want = naive::matmul(&a, &b);
        assert!(got.allclose(&want, 1e-4), "batched case {case}");
    }
}

#[test]
fn softmax_argmax_gelu_match_naive_across_parallel_cutoff() {
    let mut rng = Prng::new(0x50F7);
    // shapes straddling PAR_MIN_ELEMS (1 << 15) exercise both the
    // sequential and row-parallel dispatch paths
    let shapes: [&[usize]; 6] =
        [&[3], &[7, 11], &[2, 5, 64], &[33, 1000], &[130, 300], &[4, 64, 257]];
    for dims in shapes {
        let t = rand_tensor(&mut rng, dims);
        assert_eq!(t.softmax_last(), naive::softmax_last(&t), "softmax {dims:?}");
        assert_eq!(t.argmax_last(), naive::argmax_last(&t), "argmax {dims:?}");
        assert_eq!(t.gelu(), naive::gelu(&t), "gelu {dims:?}");
        let mut inplace = t.clone();
        inplace.softmax_last_inplace();
        assert_eq!(inplace, t.softmax_last(), "softmax_last_inplace {dims:?}");
        let mut inplace = t.clone();
        inplace.gelu_inplace();
        assert_eq!(inplace, t.gelu(), "gelu_inplace {dims:?}");
    }
}

// ---------------------------------------------------------------------------
// AOT plan properties: structural hashing and the liveness arena
// ---------------------------------------------------------------------------

use std::sync::Arc;

use nnscope::engine::{Engine, ExecSpec};
use nnscope::graph::plan::{self, PlanMode};
use nnscope::graph::plan_cache::PlanCache;
use nnscope::graph::{InterventionGraph, Op};

/// Build a trace whose *structure* (ops, layers, chain shape, scale/fill
/// factors — everything [`plan::structural_key`] hashes) comes from `st`
/// and whose *payloads* (token values, constant data, target values —
/// everything [`ExecPlan::bind`] re-stamps) come from `pay`. Two calls
/// with the same `st` seed and different `pay` seeds are structurally
/// equal by construction.
fn structured_trace(
    st: &mut Prng,
    pay: &mut Prng,
    seq: usize,
    vocab: usize,
    n_layers: usize,
) -> InterventionGraph {
    let tokens =
        Tensor::new(&[1, seq], (0..seq).map(|_| pay.range(0, vocab) as f32).collect());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let layer = st.range(0, n_layers);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    // const payload is a bind-time rebind; its dims are structural
    let clen = st.range(2, 6);
    let c = tr.constant(&Tensor::new(&[clen], (0..clen).map(|_| pay.uniform_f32()).collect()));
    let cs = tr.softmax(c);
    let cm = tr.mean(cs);
    tr.save(cm);
    let mut cur = h;
    for _ in 0..st.range(1, 5) {
        cur = match st.range(0, 4) {
            // factors are part of the computation, so they are structural:
            // draw them from `st`
            0 => tr.scale(cur, 0.5 + st.range(0, 100) as f32 * 0.01),
            1 => tr.gelu(cur),
            2 => tr.fill(
                cur,
                &[Range1::one(0), Range1::one(seq - 1)],
                st.range(0, 100) as f32 * 0.01,
            ),
            _ => tr.add(cur, h),
        };
    }
    if st.below(2) == 0 {
        tr.set_output(&point, cur);
    }
    let m = tr.mean(cur);
    tr.save(m);
    tr.into_graph()
}

#[test]
fn structurally_equal_graphs_collide_and_the_cached_plan_rebinds_correctly() {
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    for case in 0..10u64 {
        let st_seed = 0x5EED_0000 + case;
        let build = |pay_seed: u64| {
            let mut st = Prng::new(st_seed);
            let mut pay = Prng::new(pay_seed);
            structured_trace(&mut st, &mut pay, m.seq, m.vocab, m.n_layers)
        };
        let g1 = build(0xA);
        let g2 = build(0xB);
        assert_ne!(g1.nodes, g2.nodes, "case {case}: payloads failed to differ");
        let k1 = plan::structural_key(&g1, PlanMode::Trace, true);
        let k2 = plan::structural_key(&g2, PlanMode::Trace, true);
        assert_eq!(k1, k2, "case {case}: constant payloads leaked into the structural key");

        // the MUST-collide contract, end to end: warm the cache with g1,
        // run g2 through it — the hit must rebind g2's own constants and
        // tokens, not replay g1's
        let cache = Arc::new(PlanCache::new(8));
        let eng = Engine::with_plans(&runner, Arc::clone(&cache));
        let out1 = eng.run(ExecSpec::trace(&g1)).unwrap();
        let out2 = eng.run(ExecSpec::trace(&g2)).unwrap();
        let s = cache.stats();
        assert_eq!((s.misses, s.hits), (1, 1), "case {case}: {s:?}");
        let solo1 = nnscope::interp::execute(&g1, &runner).unwrap();
        let solo2 = nnscope::interp::execute(&g2, &runner).unwrap();
        assert_eq!(out1.result.values, solo1.values, "case {case}: miss path diverged");
        assert_eq!(out2.result.values, solo2.values, "case {case}: hit rebind diverged");
        assert_ne!(
            solo1.values, solo2.values,
            "case {case}: different payloads should produce different values"
        );
    }
}

#[test]
fn structurally_different_graphs_never_collide() {
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    let mut pay = Prng::new(0xF17ED);
    let mut keys = std::collections::BTreeSet::new();
    let mut graphs = 0;
    for case in 0..30u64 {
        let mut st = Prng::new(0xD1FF_0000 + case * 7919);
        let g = structured_trace(&mut st, &mut pay, m.seq, m.vocab, m.n_layers);
        // distinct structure seeds can coincide on tiny graphs; only count
        // graphs whose node lists actually differ structurally
        keys.insert(plan::structural_key(&g, PlanMode::Trace, true));
        graphs += 1;
    }
    // identical structures map to identical keys, so dedupe by building
    // each graph twice and requiring per-structure determinism instead of
    // global distinctness alone
    assert!(
        keys.len() >= graphs / 2,
        "suspicious collision rate: {} keys for {graphs} graphs",
        keys.len()
    );

    // a single structural detail — one scale factor — must change the key
    let tokens = Tensor::new(&[1, m.seq], vec![1.0; m.seq]);
    let with_factor = |f: f32| {
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        let sc = tr.scale(h, f);
        let mn = tr.mean(sc);
        tr.save(mn);
        plan::structural_key(&tr.into_graph(), PlanMode::Trace, true)
    };
    assert_ne!(with_factor(0.5), with_factor(0.75), "scale factor is structural");
    assert_eq!(with_factor(0.5), with_factor(0.5), "hashing is deterministic");
}

#[test]
fn no_two_simultaneously_live_values_share_an_arena_slot() {
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    let fseq = m.forward_sequence();
    let mut rng = Prng::new(0xA2E4A);
    let mut reuse_seen = false;
    for case in 0..40 {
        let g = random_trace(&mut rng, m.seq, m.vocab, m.n_layers).into_graph();
        let order = plan::execution_order(&g, &fseq).unwrap();
        let locked = plan::locked_flags(&g);
        let mp = plan::plan_memory(&g, &order, &locked);

        // independent liveness re-simulation over the planner's own
        // linear order: pre, hooks in forward order, grads, then the rest
        // of the post phase
        let mut linear: Vec<usize> = order.pre.clone();
        for hook in &order.fwd {
            linear.extend(hook.iter().copied());
        }
        linear.extend(
            order.post.iter().copied().filter(|&i| matches!(g.nodes[i].op, Op::Grad { .. })),
        );
        linear.extend(
            order.post.iter().copied().filter(|&i| !matches!(g.nodes[i].op, Op::Grad { .. })),
        );
        assert_eq!(linear.len(), g.nodes.len(), "case {case}: order lost nodes");

        let init = g.listener_counts();
        let mut listeners = init.clone();
        let mut occupant: std::collections::BTreeMap<usize, usize> = Default::default();
        let mut peak = 0usize;
        for &id in &linear {
            for d in g.nodes[id].op.deps() {
                listeners[d] = listeners[d].saturating_sub(1);
                if listeners[d] == 0 && !locked[d] {
                    if let Some(s) = mp.slot_of[d] {
                        occupant.remove(&s);
                    }
                }
            }
            // the materialization rule: a value gets a slot iff something
            // will ever read it or a Save/StepHook locked it
            assert_eq!(
                mp.slot_of[id].is_some(),
                init[id] > 0 || locked[id],
                "case {case} node {id}: materialization rule violated"
            );
            if let Some(s) = mp.slot_of[id] {
                // THE invariant: the slot must be free while this value is
                // born — two simultaneously-live values never share
                if let Some(&other) = occupant.get(&s) {
                    panic!(
                        "case {case}: node {id} placed in slot {s} while node \
                         {other} is still live there"
                    );
                }
                occupant.insert(s, id);
                peak = peak.max(occupant.len());
                assert!(s < mp.n_slots, "case {case}: slot {s} out of arena bounds");
            }
        }
        assert_eq!(
            peak, mp.n_slots,
            "case {case}: arena size must equal peak simultaneous residency"
        );
        let materialized = mp.slot_of.iter().filter(|s| s.is_some()).count();
        assert!(mp.n_slots <= materialized, "case {case}");
        if mp.n_slots < materialized {
            reuse_seen = true;
        }
    }
    assert!(reuse_seen, "workload never reused a slot — planner inert?");
}

#[test]
fn planned_peak_bytes_never_exceed_unplanned_peak() {
    use nnscope::client::remote::NdifClient;
    use nnscope::client::ExecuteOptions;
    use nnscope::server::{NdifConfig, NdifServer};
    let probe = |plan_cache: bool| {
        let mut cfg = NdifConfig::local(&["tiny-sim"]);
        cfg.plan_cache = plan_cache;
        let server = NdifServer::start(cfg).unwrap();
        let client = NdifClient::new(server.addr());
        let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        let mut cur = h;
        for _ in 0..6 {
            cur = tr.gelu(cur);
        }
        let mn = tr.mean(cur);
        tr.save(mn);
        let out = client.run(tr.graph(), ExecuteOptions::new().profiled()).unwrap();
        out.profile
            .expect("profiled run must attach a profile")
            .get("peak_bytes")
            .as_i64()
            .expect("profile must carry peak_bytes")
    };
    let unplanned = probe(false);
    let planned = probe(true);
    assert!(planned > 0 && unplanned > 0);
    assert!(
        planned <= unplanned,
        "liveness-planned execution must not hold more bytes than \
         per-node allocation: planned {planned} vs unplanned {unplanned}"
    );
}

#[test]
fn executor_frees_values_along_random_chains() {
    use nnscope::graph::{InterventionGraph, Op, Port};
    use nnscope::interp::Executor;
    let fseq: Vec<String> = vec!["embed".into(), "layer.0".into(), "lm_head".into()];
    let mut rng = Prng::new(0xF2EE);
    for _ in 0..50 {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let mut cur = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let len = rng.range(2, 20);
        for _ in 0..len {
            cur = g.push(Op::Scale { arg: cur, factor: 0.9 });
        }
        g.push(Op::Save { arg: cur });
        let mut ex = Executor::new(&g, &fseq).unwrap();
        ex.run_pre().unwrap();
        let mut t = Tensor::iota(&[1, 4]);
        assert!(ex.wants("layer.0"));
        ex.on_output("layer.0", &mut t);
        // linear chain: at most two unlocked values live at any time
        assert!(ex.peak_live() <= 2, "peak {}", ex.peak_live());
    }
}
