//! Property tests over the full stack: randomly generated intervention
//! graphs must (a) round-trip the wire format, (b) agree between scan's
//! predicted shapes and executed shapes, (c) never corrupt co-tenant
//! neighbours, and (d) never crash the server even when mangled.

use nnscope::client::Trace;
use nnscope::graph::serde as gserde;
use nnscope::json::parse;
use nnscope::models::{artifacts_dir, Hooks, ModelRunner};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::Prng;

/// Build a random-but-valid trace over tiny-sim.
fn random_trace(rng: &mut Prng, seq: usize, vocab: usize, n_layers: usize) -> Trace {
    let batch = rng.range(1, 3); // 1 or 2 rows (exported batches 1,4)
    let tokens = Tensor::new(
        &[batch, seq],
        (0..batch * seq).map(|_| rng.range(0, vocab) as f32).collect(),
    );
    let mut tr = Trace::new("tiny-sim", &tokens);
    let layer = rng.range(0, n_layers);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    // a random chain of shape-preserving ops
    let mut cur = h;
    for _ in 0..rng.range(0, 4) {
        cur = match rng.range(0, 4) {
            0 => tr.scale(cur, 0.5 + rng.uniform_f32()),
            1 => tr.gelu(cur),
            2 => tr.add(cur, h),
            _ => {
                let f = rng.uniform_f32();
                tr.fill(cur, &[Range1::one(0), Range1::one(seq - 1)], f)
            }
        };
    }
    // maybe write it back (valid: same module)
    if rng.below(2) == 0 {
        tr.set_output(&point, cur);
    }
    // read somewhere downstream and reduce
    let later = rng.range(layer, n_layers);
    let h2 = tr.output(&format!("layer.{later}"));
    let m = tr.mean(h2);
    tr.save(m);
    tr.save(cur);
    tr
}

#[test]
fn random_graphs_scan_execute_and_round_trip() {
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    let mut rng = Prng::new(0x5EED);
    for case in 0..25 {
        let tr = random_trace(&mut rng, m.seq, m.vocab, m.n_layers);
        // wire round trip preserves the graph
        let g = tr.graph().clone();
        let wire = gserde::to_json(&g).to_string();
        let back = gserde::from_json(&parse(&wire).unwrap()).unwrap();
        assert_eq!(back.nodes, g.nodes, "case {case}");

        // scan's shapes match executed shapes for every save
        let shapes = tr.scan(&m).unwrap_or_else(|e| panic!("case {case}: scan {e}"));
        let res = tr
            .run_local(&runner)
            .unwrap_or_else(|e| panic!("case {case}: exec {e}"));
        for (id, t) in &res.inner().values {
            // the save node's shape equals its dependency's shape
            assert_eq!(
                t.dims(),
                &shapes[*id][..],
                "case {case}: node {id} shape mismatch"
            );
            assert!(t.data().iter().all(|v| v.is_finite()), "case {case}");
        }
    }
}

#[test]
fn random_cotenant_merges_preserve_solo_results() {
    use nnscope::scheduler::execute_merged;
    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let m = runner.manifest.clone();
    let mut rng = Prng::new(0xC0C0);
    for case in 0..10 {
        // two single-row graphs (fit in batch 4 together)
        let mut graphs = Vec::new();
        for _ in 0..2 {
            let tokens = Tensor::new(
                &[1, m.seq],
                (0..m.seq).map(|_| rng.range(0, m.vocab) as f32).collect(),
            );
            let mut tr = Trace::new("tiny-sim", &tokens);
            let layer = rng.range(0, m.n_layers);
            let point = format!("layer.{layer}");
            let h = tr.output(&point);
            if rng.below(2) == 0 {
                let z = tr.scale(h, rng.uniform_f32());
                tr.set_output(&point, z);
            }
            let logits = tr.output("lm_head");
            tr.save(logits);
            graphs.push(tr.into_graph());
        }
        let solo: Vec<_> = graphs
            .iter()
            .map(|g| nnscope::interp::execute(g, &runner).unwrap())
            .collect();
        let merged = execute_merged(&graphs, &runner).unwrap();
        for (i, (s, mr)) in solo.iter().zip(&merged).enumerate() {
            let mr = mr.as_ref().unwrap();
            for (id, t) in &s.values {
                assert!(
                    mr.values[id].allclose(t, 1e-4),
                    "case {case} graph {i} node {id}: diff {}",
                    mr.values[id].max_abs_diff(t)
                );
            }
        }
    }
}

#[test]
fn mangled_requests_never_crash_the_server() {
    use nnscope::server::{http, NdifConfig, NdifServer};
    let server = NdifServer::start(NdifConfig::local(&["tiny-sim"])).unwrap();
    let addr = server.addr();

    // a valid request to mutate
    let runner_manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), "tiny-sim").unwrap();
    let tokens = Tensor::zeros(&[1, runner_manifest.seq]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let valid = gserde::to_json(tr.graph()).to_string();

    let mut rng = Prng::new(0xFA22);
    for _ in 0..40 {
        let mut bytes = valid.clone().into_bytes();
        match rng.range(0, 4) {
            0 => {
                // truncate
                let cut = rng.range(0, bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                // flip a byte
                let i = rng.range(0, bytes.len());
                bytes[i] = bytes[i].wrapping_add(rng.below(255) as u8 + 1);
            }
            2 => {
                // duplicate a chunk
                let i = rng.range(0, bytes.len());
                let chunk: Vec<u8> = bytes[i..].to_vec();
                bytes.extend_from_slice(&chunk);
            }
            _ => {
                // random garbage
                bytes = (0..rng.range(1, 200)).map(|_| rng.below(256) as u8).collect();
            }
        }
        // must answer (with any status), not hang or die
        let (status, _) = http::post(addr, "/v1/trace", &bytes).expect("server alive");
        assert!(status == 202 || status == 400 || status == 404 || status == 401);
    }

    // the server still works after the fuzzing
    let (status, _) = http::get(addr, "/health").unwrap();
    assert_eq!(status, 200);
    let client = nnscope::client::remote::NdifClient::new(addr);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let s = tr.save(h);
    let res = tr.run_remote(&client).unwrap();
    assert_eq!(res.get(s).dims(), &[1, 16, 32]);
}

#[test]
fn executor_frees_values_along_random_chains() {
    use nnscope::graph::{InterventionGraph, Op, Port};
    use nnscope::interp::Executor;
    let fseq: Vec<String> = vec!["embed".into(), "layer.0".into(), "lm_head".into()];
    let mut rng = Prng::new(0xF2EE);
    for _ in 0..50 {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let mut cur = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let len = rng.range(2, 20);
        for _ in 0..len {
            cur = g.push(Op::Scale { arg: cur, factor: 0.9 });
        }
        g.push(Op::Save { arg: cur });
        let mut ex = Executor::new(&g, &fseq).unwrap();
        ex.run_pre().unwrap();
        let mut t = Tensor::iota(&[1, 4]);
        assert!(ex.wants("layer.0"));
        ex.on_output("layer.0", &mut t);
        // linear chain: at most two unlocked values live at any time
        assert!(ex.peak_live() <= 2, "peak {}", ex.peak_live());
    }
}
