//! Deterministic fault-injection suite: the chaos tests behind the
//! fabric's durability and isolation claims.
//!
//! * a replica crash after completion loses zero journaled results, and
//!   restart delivers each exactly once;
//! * a torn journal tail (crash mid-record) is truncated, not fatal;
//! * a tenant hammering at 10× its rate limit gets clean 429s while other
//!   tenants' latency stays within budget;
//! * load shedding drops anonymous work first and admitted work rides out;
//! * dropped heartbeats inside the hysteresis window do not flap health;
//! * injected dispatch faults exercise the real failover bookkeeping;
//! * concurrent clients hammering a pinned session through a replica death
//!   all get an answer (success or retryable) in bounded time — no hangs.
//!
//! The failpoint registry is process-global, so every test here holds
//! `FP_LOCK`: a failpoint armed by one test must never leak into the
//! fabric traffic of another running in a parallel test thread.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use nnscope::client::remote::NdifClient;
use nnscope::client::retry::is_retryable;
use nnscope::client::{RetryPolicy, Session, Trace};
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::json::Json;
use nnscope::server::store::{Entry, ObjectStore};
use nnscope::server::{http, NdifConfig, NdifServer, RateLimit, ShedPolicy};
use nnscope::tensor::Tensor;
use nnscope::util::failpoint::{self, Armed, FailAction, Spec};

static FP_LOCK: Mutex<()> = Mutex::new(());

fn fp_lock() -> std::sync::MutexGuard<'static, ()> {
    FP_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tokens(v: f32) -> Tensor {
    Tensor::new(&[1, 16], vec![v; 16])
}

/// Wire payload of a minimal save-one-activation trace.
fn trace_payload(v: f32) -> String {
    let mut tr = Trace::new("tiny-sim", &tokens(v));
    let h = tr.output("layer.0");
    tr.save(h);
    nnscope::graph::serde::to_json(&tr.into_graph()).to_string()
}

fn get_json(addr: SocketAddr, path: &str) -> Json {
    let (status, body) = http::get(addr, path).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    nnscope::json::parse(std::str::from_utf8(&body).unwrap()).unwrap()
}

fn fault_counter(addr: SocketAddr, key: &str) -> i64 {
    get_json(addr, "/v1/metrics").get("_faults").get(key).as_i64().unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "nnscope-faultinj-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Submit a trace and wait until the worker completes it WITHOUT picking
/// up the result — the completed-but-undelivered window a crash must not
/// lose.
fn submit_and_complete(server: &NdifServer, v: f32) -> String {
    let (_, before, _, _) = server.metrics("tiny-sim").unwrap();
    let (status, body) =
        http::post(server.addr(), "/v1/trace", trace_payload(v).as_bytes()).unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = nnscope::json::parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, completed, failed, _) = server.metrics("tiny-sim").unwrap();
        assert_eq!(failed, 0);
        if completed > before {
            return id;
        }
        assert!(Instant::now() < deadline, "worker never completed the trace");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// Tier 1: durable results
// ---------------------------------------------------------------------------

#[test]
fn crash_after_completion_loses_nothing_and_delivers_exactly_once() {
    let _fp = fp_lock();
    let dir = tmpdir("restart");

    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.data_dir = Some(dir.clone());
    let mut server = NdifServer::start(cfg).unwrap();
    let id = submit_and_complete(&server, 3.0);
    // crash: no graceful drain, no journal sync
    server.kill();
    drop(server);

    // restart on the same data dir: the completed result must be there
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.data_dir = Some(dir.clone());
    let server2 = NdifServer::start(cfg).unwrap();
    assert!(
        fault_counter(server2.addr(), "journal_replayed") >= 1,
        "restart must replay the journaled result"
    );
    let (status, body) =
        http::get(server2.addr(), &format!("/v1/result/{id}?timeout_ms=2000")).unwrap();
    assert_eq!(status, 200, "replayed result must be deliverable: {}",
        String::from_utf8_lossy(&body));
    assert!(!body.is_empty());

    // exactly once: the pickup evicted it
    let (status, _) =
        http::get(server2.addr(), &format!("/v1/result/{id}?timeout_ms=100")).unwrap();
    assert_eq!(status, 404, "second pickup of the same id must 404");

    // the id counter resumed past the replayed ids: no reuse
    let (status, body) =
        http::post(server2.addr(), "/v1/trace", trace_payload(4.0).as_bytes()).unwrap();
    assert_eq!(status, 202);
    let fresh = nnscope::json::parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();
    assert_ne!(fresh, id, "restart must not mint a replayed id again");
    drop(server2);

    // the eviction itself was journaled: a third incarnation still 404s
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.data_dir = Some(dir.clone());
    let server3 = NdifServer::start(cfg).unwrap();
    let (status, _) =
        http::get(server3.addr(), &format!("/v1/result/{id}?timeout_ms=100")).unwrap();
    assert_eq!(status, 404, "delivered results must not resurrect across restarts");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_journal_tail_is_truncated_not_fatal() {
    let _fp = fp_lock();
    let dir = tmpdir("torn");

    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.data_dir = Some(dir.clone());
    let mut server = NdifServer::start(cfg).unwrap();
    let id = submit_and_complete(&server, 5.0);
    server.kill();
    drop(server);

    // simulate a crash that landed mid-append: magic byte + half a length
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("store.journal"))
            .unwrap();
        f.write_all(&[0xA7, 0x10, 0x00]).unwrap();
    }

    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.data_dir = Some(dir.clone());
    let server2 = NdifServer::start(cfg).unwrap();
    assert!(
        fault_counter(server2.addr(), "journal_truncated_bytes") >= 3,
        "the torn tail must be counted"
    );
    // every record before the tear survived
    let (status, _) =
        http::get(server2.addr(), &format!("/v1/result/{id}?timeout_ms=2000")).unwrap();
    assert_eq!(status, 200, "records before the torn tail must replay");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_put_failpoint_drops_exactly_the_guarded_write() {
    let _fp = fp_lock();
    let store = ObjectStore::new();
    {
        let _g = Armed::new("store.put", Spec::nth(0, FailAction::Skip));
        store.put_ready("x", "{}".into());
        assert!(store.peek("x").is_none(), "the armed write must be lost");
    }
    store.put_ready("x", "{}".into());
    assert!(matches!(store.peek("x"), Some(Entry::Ready(_))), "disarmed writes land");
}

// ---------------------------------------------------------------------------
// Tier 2: per-tenant admission control
// ---------------------------------------------------------------------------

#[test]
fn tenant_at_10x_limit_gets_429s_without_collateral_damage() {
    let _fp = fp_lock();
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.rate_limit = Some(RateLimit::new(50.0, 10.0));
    let server = NdifServer::start(cfg).unwrap();
    let addr = server.addr();

    let polite = NdifClient::new(addr).with_token("polite");
    let run_polite = |n: usize, base: f32| -> Vec<Duration> {
        (0..n)
            .map(|i| {
                let mut tr = Trace::new("tiny-sim", &tokens(base + i as f32));
                let h = tr.output("layer.0");
                tr.save(h);
                let t0 = Instant::now();
                tr.run_remote(&polite).unwrap();
                let dt = t0.elapsed();
                std::thread::sleep(Duration::from_millis(30));
                dt
            })
            .collect()
    };
    let p95 = |mut v: Vec<Duration>| -> Duration {
        v.sort();
        v[((v.len() as f64 * 0.95) as usize).min(v.len() - 1)]
    };

    let base = p95(run_polite(12, 0.0));

    // the hog hammers the front door far past 10× its sustained rate.
    // Bodies are deliberately unparsable so the test isolates the token
    // bucket from queue contention (the per-tenant queue cap covers that).
    let stop = Arc::new(AtomicBool::new(false));
    let hog = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let (mut n429, mut attempts) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                let (status, body) = http::http_request(
                    addr,
                    "POST",
                    "/v1/trace",
                    b"not a graph",
                    &[("x-ndif-auth", "hog")],
                )
                .unwrap();
                attempts += 1;
                if status == 429 {
                    let s = String::from_utf8_lossy(&body);
                    assert!(s.contains("\"retryable\":true"), "{s}");
                    assert!(s.contains("retry_after_ms"), "{s}");
                    n429 += 1;
                }
            }
            (n429, attempts)
        })
    };

    let during = p95(run_polite(12, 100.0));
    stop.store(true, Ordering::Relaxed);
    let (n429, attempts) = hog.join().unwrap();

    assert!(attempts >= 100, "hog only managed {attempts} attempts");
    assert!(
        n429 * 10 >= attempts * 8,
        "a tenant far over its limit must be mostly throttled: {n429}/{attempts}"
    );
    assert!(fault_counter(addr, "throttled") as u64 >= n429);
    // the polite tenant's p95 stays within 2× its baseline (plus a small
    // absolute floor absorbing scheduler jitter on millisecond latencies)
    let budget = (base * 2).max(Duration::from_millis(120));
    assert!(
        during <= budget,
        "polite p95 {during:?} blew past 2× baseline {base:?}"
    );
}

#[test]
fn load_shed_drops_anonymous_first_and_admitted_ride_out() {
    let _fp = fp_lock();
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.shed = ShedPolicy { shed_anon_above: 0, shed_all_above: 1000 };
    let server = NdifServer::start(cfg).unwrap();
    let addr = server.addr();
    // the stream that builds the backlog is authenticated, so it cannot
    // itself be shed at the anon watermark
    let client = NdifClient::new(addr).with_token("vip");

    // with nothing queued, anonymous work is admitted
    let (status, _) = http::post(addr, "/v1/trace", trace_payload(0.0).as_bytes()).unwrap();
    assert_eq!(status, 202, "below the watermark nothing is shed");
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.metrics("tiny-sim").unwrap().1 < 1 {
        assert!(Instant::now() < deadline, "warmup trace never completed");
        std::thread::sleep(Duration::from_millis(5));
    }

    // occupy the worker: a stream whose every frame is delayed keeps the
    // queue depth above the anon watermark for a deterministic window
    let _slow = Armed::new("stream.frame", Spec::always(FailAction::Delay(Duration::from_millis(40))));
    let mut tr = Trace::new("tiny-sim", &tokens(1.0));
    let h = tr.output("layer.0");
    let m = tr.mean(h);
    tr.step_hook(m);
    let mut stream = tr.run_stream(&client, 30).unwrap();
    let first = stream.next().expect("stream yields").unwrap();
    drop(first);

    // anonymous: shed with a retryable 503
    let (status, body) = http::post(addr, "/v1/trace", trace_payload(2.0).as_bytes()).unwrap();
    let s = String::from_utf8_lossy(&body);
    assert_eq!(status, 503, "{s}");
    assert!(s.contains("\"retryable\":true"), "{s}");
    assert!(s.contains("shed"), "{s}");

    // authenticated: rides out the first watermark
    let (status, body) = http::http_request(
        addr,
        "POST",
        "/v1/trace",
        trace_payload(3.0).as_bytes(),
        &[("x-ndif-auth", "vip")],
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    assert!(fault_counter(addr, "shed") >= 1);

    // drain the stream so the worker finishes cleanly
    for ev in stream {
        ev.unwrap();
    }
}

#[test]
fn client_retry_rides_out_throttling_end_to_end() {
    let _fp = fp_lock();
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.rate_limit = Some(RateLimit::new(20.0, 1.0));
    let server = NdifServer::start(cfg).unwrap();
    let client = NdifClient::new(server.addr()).with_token("steady");
    let policy = RetryPolicy::new(
        8,
        Duration::from_millis(5),
        Duration::from_millis(300),
        Duration::from_secs(10),
        42,
    );

    for i in 0..6 {
        let mut tr = Trace::new("tiny-sim", &tokens(i as f32));
        let h = tr.output("layer.0");
        tr.save(h);
        let g = tr.into_graph();
        client
            .run(&g, nnscope::client::ExecuteOptions::new().retry(policy.clone()))
            .expect("retry policy must ride out 429s");
    }
    assert!(
        fault_counter(server.addr(), "throttled") >= 1,
        "burst=1 back-to-back submits must have throttled at least once"
    );
}

// ---------------------------------------------------------------------------
// Tier 3/4: fleet chaos — heartbeats, dispatch faults, pinned sessions
// ---------------------------------------------------------------------------

fn coordinator() -> Coordinator {
    let mut cfg = CoordinatorConfig::local();
    cfg.policy = Policy::LeastLoaded;
    cfg.probe_interval = Duration::from_millis(50);
    cfg.health.degraded_after = Duration::from_millis(400);
    cfg.health.dead_after = Duration::from_secs(2);
    Coordinator::start(cfg).unwrap()
}

fn replica(coord: &Coordinator) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.coordinator = Some(coord.addr().to_string());
    cfg.heartbeat = Duration::from_millis(50);
    NdifServer::start(cfg).unwrap()
}

#[test]
fn dropped_heartbeats_inside_hysteresis_window_do_not_flap_health() {
    let _fp = fp_lock();
    let coord = coordinator();
    let _r = replica(&coord);
    let client = NdifClient::new(coord.addr());
    // wait for registration
    let deadline = Instant::now() + Duration::from_secs(5);
    while client.fleet_status().unwrap().get("replicas").as_array().unwrap().is_empty() {
        assert!(Instant::now() < deadline, "replica never registered");
        std::thread::sleep(Duration::from_millis(20));
    }

    // drop 4 consecutive heartbeats (~200 ms of silence at a 50 ms
    // cadence) — well inside the 400 ms degradation window
    let _g = Armed::new(
        "replica.heartbeat",
        Spec { skip: 0, take: 4, prob: 1.0, seed: 0, action: FailAction::Skip },
    );
    let until = Instant::now() + Duration::from_millis(350);
    while Instant::now() < until {
        for r in client.fleet_status().unwrap().get("replicas").as_array().unwrap() {
            assert_eq!(
                r.get("health").as_str(),
                Some("alive"),
                "a blip inside the hysteresis window must not flap health"
            );
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(failpoint::fired("replica.heartbeat") >= 1, "the failpoint must have fired");

    // and the fabric still serves
    let mut tr = Trace::new("tiny-sim", &tokens(1.0));
    let h = tr.output("layer.0");
    tr.save(h);
    tr.run_remote(&client).unwrap();
}

#[test]
fn injected_dispatch_fault_fails_over_to_a_survivor() {
    let _fp = fp_lock();
    let coord = coordinator();
    let r1 = replica(&coord);
    let r2 = replica(&coord);
    let client = NdifClient::new(coord.addr());

    let _g = Armed::new(
        "coord.dispatch",
        Spec::nth(0, FailAction::Error("chaos monkey".into())),
    );
    let mut tr = Trace::new("tiny-sim", &tokens(7.0));
    let h = tr.output("layer.0");
    let s = tr.save(h);
    let res = tr.run_remote(&client).expect("failover must absorb the injected fault");
    assert_eq!(res.get(s).dims(), &[1, 16, 32]);
    assert_eq!(failpoint::fired("coord.dispatch"), 1);

    // exactly one replica executed it — the faulted dispatch never ran
    let (_, c1, _, _) = r1.metrics("tiny-sim").unwrap();
    let (_, c2, _, _) = r2.metrics("tiny-sim").unwrap();
    assert_eq!(c1 + c2, 1, "the request ran exactly once ({c1}/{c2})");
}

/// A self-contained bundle against a named (pinned) session: stores and
/// saves in one request, so recovery after an unpin is a clean re-run.
fn pinned_bundle(v: f32) -> Session {
    let mut session = Session::new().with_id("pinned");
    let mut t = Trace::new("tiny-sim", &tokens(v));
    let c = t.constant(&Tensor::scalar(v));
    t.save_to_state("w", c);
    t.save(c);
    session.add(t);
    session
}

#[test]
fn concurrent_pinned_session_hammer_through_replica_death_never_hangs() {
    let _fp = fp_lock();
    let t0 = Instant::now();
    let coord = coordinator();
    let r1 = replica(&coord);
    let r2 = replica(&coord);
    let addr = coord.addr();

    // establish the pin, then find the replica holding it
    pinned_bundle(1.0).run_remote(&NdifClient::new(addr)).unwrap();
    let mut replicas = [r1, r2];
    let holder = replicas
        .iter()
        .position(|r| matches!(http::get(r.addr(), "/v1/session/pinned"), Ok((200, _))))
        .expect("some replica holds the pinned session");

    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..6)
        .map(|t| {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                let (mut ok, mut ok_post, mut retryable) = (0u32, 0u32, 0u32);
                let mut i = 0u32;
                // hammer until a success lands AFTER the kill has settled —
                // proof this client reached the surviving replica
                loop {
                    let settled = stop.load(Ordering::Relaxed);
                    if settled && ok_post > 0 {
                        break;
                    }
                    i += 1;
                    assert!(i < 10_000, "thread {t} starved");
                    match pinned_bundle((t * 1000 + i) as f32).run_remote(&client) {
                        Ok(_) => {
                            ok += 1;
                            if settled {
                                ok_post += 1;
                            }
                        }
                        Err(e) => {
                            assert!(
                                is_retryable(&e),
                                "every failure across the death must be retryable: {e}"
                            );
                            retryable += 1;
                            std::thread::sleep(Duration::from_millis(20));
                        }
                    }
                }
                (ok, retryable)
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(100));
    replicas[holder].kill();
    // after the registry marks the death, fresh placements go to the
    // survivor; threads exit once they see a post-kill success
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Ordering::Relaxed);

    let mut total_ok = 0;
    for h in handles {
        let (ok, _retryable) = h.join().unwrap();
        assert!(ok > 0, "every client must eventually reach the new replica");
        total_ok += ok;
    }
    assert!(total_ok >= 6);
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "the hammer must converge in bounded time"
    );
}
