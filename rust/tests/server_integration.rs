//! NDIF server integration: loopback remote execution must agree with
//! local execution; auth, sessions, co-tenancy, error paths, and
//! concurrent clients all exercise the real HTTP + queue + store stack.

use std::collections::HashMap;

use nnscope::client::{remote::NdifClient, Session, Trace};
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::{Range1, Tensor};

fn start_server(cotenancy: CoTenancy) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.cotenancy = cotenancy;
    NdifServer::start(cfg).unwrap()
}

fn patch_trace(tokens: &Tensor) -> (Trace, nnscope::client::SavedRef) {
    let mut tr = Trace::new("tiny-sim", tokens);
    let h = tr.output("layer.0");
    let filled = tr.fill(h, &[Range1::one(0), Range1::one(15)], 0.5);
    tr.set_output("layer.0", filled);
    let logits = tr.output("lm_head");
    let s = tr.save(logits);
    (tr, s)
}

#[test]
fn remote_equals_local() {
    let server = start_server(CoTenancy::Sequential);
    let client = NdifClient::new(server.addr());
    assert!(client.health().unwrap());
    assert_eq!(client.models().unwrap(), vec!["tiny-sim".to_string()]);

    let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());

    let (tr, s) = patch_trace(&tokens);
    let local = tr.run_local(&runner).unwrap();

    let (tr, s2) = patch_trace(&tokens);
    let remote = tr.run_remote(&client).unwrap();

    assert!(
        local.get(s).allclose(remote.get(s2), 1e-5),
        "remote/local divergence {}",
        local.get(s).max_abs_diff(remote.get(s2))
    );
}

#[test]
fn remote_session_round_trip() {
    let server = start_server(CoTenancy::Sequential);
    let client = NdifClient::new(server.addr());
    let tokens = Tensor::new(&[1, 16], vec![1.0; 16]);

    let mut session = Session::new();
    let mut t1 = Trace::new("tiny-sim", &tokens);
    let h = t1.output("layer.0");
    let s1 = t1.save(h);
    session.add(t1);
    let mut t2 = Trace::new("tiny-sim", &tokens);
    let h = t2.output("layer.1");
    let s2 = t2.save(h);
    session.add(t2);

    let results = session.run_remote(&client).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].get(s1).dims(), &[1, 16, 32]);
    assert_eq!(results[1].get(s2).dims(), &[1, 16, 32]);
}

#[test]
fn auth_gates_models() {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.auth = HashMap::from([("tiny-sim".to_string(), vec!["sesame".to_string()])]);
    let server = NdifServer::start(cfg).unwrap();
    let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);

    // no token: rejected
    let client = NdifClient::new(server.addr());
    let (tr, _) = patch_trace(&tokens);
    let err = tr.run_remote(&client).unwrap_err().to_string();
    assert!(err.contains("401") || err.contains("authorized"), "{err}");

    // wrong token: rejected
    let client = NdifClient::new(server.addr()).with_token("wrong");
    let (tr, _) = patch_trace(&tokens);
    assert!(tr.run_remote(&client).is_err());

    // right token: accepted
    let client = NdifClient::new(server.addr()).with_token("sesame");
    let (tr, s) = patch_trace(&tokens);
    let res = tr.run_remote(&client).unwrap();
    assert_eq!(res.get(s).dims(), &[1, 16, 64]);
}

#[test]
fn bad_requests_rejected_cleanly() {
    let server = start_server(CoTenancy::Sequential);
    let addr = server.addr();

    // malformed json
    let (status, _) = nnscope::server::http::post(addr, "/v1/trace", b"{not json").unwrap();
    assert_eq!(status, 400);

    // unknown model
    let (status, _) = nnscope::server::http::post(
        addr,
        "/v1/trace",
        br#"{"model":"gpt-17","batch":1,"tokens":[],"nodes":[]}"#,
    )
    .unwrap();
    assert_eq!(status, 404);

    // invalid graph (unknown module)
    let (status, body) = nnscope::server::http::post(
        addr,
        "/v1/trace",
        br#"{"model":"tiny-sim","batch":1,"tokens":[0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0],
             "nodes":[{"id":0,"op":"getter","module":"layer.9","port":"output"}]}"#,
    )
    .unwrap();
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));

    // unknown result id
    let (status, _) =
        nnscope::server::http::get(addr, "/v1/result/r-404?timeout_ms=10").unwrap();
    assert_eq!(status, 404);
}

#[test]
fn result_timeout_query_parsing() {
    let server = start_server(CoTenancy::Sequential);
    let addr = server.addr();

    // timeout_ms is honored anywhere in a multi-parameter query
    let (status, _) =
        nnscope::server::http::get(addr, "/v1/result/r-404?x=1&timeout_ms=10").unwrap();
    assert_eq!(status, 404);
    let (status, _) =
        nnscope::server::http::get(addr, "/v1/result/r-404?timeout_ms=10&x=1").unwrap();
    assert_eq!(status, 404);

    // non-numeric or empty timeout_ms → 400, not a silent default
    let (status, body) =
        nnscope::server::http::get(addr, "/v1/result/r-404?timeout_ms=abc").unwrap();
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let (status, _) =
        nnscope::server::http::get(addr, "/v1/result/r-404?timeout_ms=").unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        nnscope::server::http::get(addr, "/v1/result/r-404?timeout_ms=-5").unwrap();
    assert_eq!(status, 400);

    // unknown parameters alone are ignored (default timeout applies)
    let (status, _) = nnscope::server::http::get(addr, "/v1/result/r-404?x=1").unwrap();
    assert_eq!(status, 404);
}

#[test]
fn concurrent_clients_parallel_cotenancy() {
    let server = start_server(CoTenancy::Parallel { max_merge: 4 });
    let addr = server.addr();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                let tokens = Tensor::new(&[1, 16], vec![i as f32; 16]);
                let mut tr = Trace::new("tiny-sim", &tokens);
                let h = tr.output("layer.0");
                let s = tr.save(h);
                let res = tr.run_remote(&client).unwrap();
                // each user's activation depends on their own tokens
                res.get(s).data()[0]
            })
        })
        .collect();
    let vals: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // different tokens → different activations (no cross-tenant bleed)
    let distinct: std::collections::BTreeSet<_> =
        vals.iter().map(|v| (v * 1e6) as i64).collect();
    assert!(distinct.len() > 4, "activations suspiciously identical: {vals:?}");
    let (enq, done, failed, _merged) = server.metrics("tiny-sim").unwrap();
    assert_eq!(enq, 8);
    assert_eq!(done, 8);
    assert_eq!(failed, 0);
}

#[test]
fn server_side_error_is_reported_per_request() {
    let server = start_server(CoTenancy::Sequential);
    let client = NdifClient::new(server.addr());
    // tokens length mismatch (batch 2 declared, 1 row of tokens) passes
    // validation but fails at execution
    let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    tr.save(h);
    let mut g = tr.into_graph();
    g.batch = 2; // corrupt
    let err = client
        .run(&g, nnscope::client::ExecuteOptions::new())
        .unwrap_err()
        .to_string();
    assert!(err.contains("remote execution failed"), "{err}");
}

/// The deprecated pre-`ExecuteOptions` client surface still works through
/// its shims. This test is deliberately the only in-repo caller of the old
/// names; everything else goes through [`NdifClient::run`] and friends.
#[test]
#[allow(deprecated)]
fn deprecated_execute_shims_still_work() {
    let Ok(server) = NdifServer::start(NdifConfig::local(&["tiny-sim"])) else {
        return; // no artifacts in this environment
    };
    let client = NdifClient::new(server.addr());
    let tokens = Tensor::new(&[1, 16], vec![1.0; 16]);

    let mk = || {
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        tr.save(h);
        tr.into_graph()
    };

    let r = client.execute(&mk()).unwrap();
    assert_eq!(r.values.len(), 1);
    let (r, _report) = client.execute_detailed(&mk()).unwrap();
    assert_eq!(r.values.len(), 1);
    let (r, _report, _timing) = client.execute_observed(&mk()).unwrap();
    assert_eq!(r.values.len(), 1);
    let (r, profile, _id) = client.execute_profiled(&mk()).unwrap();
    assert_eq!(r.values.len(), 1);
    assert!(profile.get("ops").as_i64().unwrap_or(0) > 0);
    let r = client
        .execute_with_retry(&mk(), &nnscope::client::RetryPolicy::none())
        .unwrap();
    assert_eq!(r.values.len(), 1);

    // fetch_result re-reads a completed request by id
    let id = client
        .run(&mk(), nnscope::client::ExecuteOptions::new())
        .unwrap()
        .id;
    let r = client.fetch_result(&id).unwrap();
    assert_eq!(r.values.len(), 1);

    let rs = client.execute_session(&[mk(), mk()]).unwrap();
    assert_eq!(rs.len(), 2);

    let events: Vec<_> = client
        .execute_stream(&mk(), 2)
        .unwrap()
        .collect::<Result<Vec<_>, _>>()
        .unwrap();
    assert!(events.len() >= 2, "{} stream events", events.len());
}

#[test]
fn netsim_accounts_payload_bytes() {
    use nnscope::netsim::{Mode, NetSim};
    let server = start_server(CoTenancy::Sequential);
    let link = NetSim::new(0.0, 1e9, Mode::Account);
    let client = NdifClient::new(server.addr()).with_link(link.clone());
    let tokens = Tensor::new(&[1, 16], vec![0.0; 16]);
    let (tr, _) = patch_trace(&tokens);
    tr.run_remote(&client).unwrap();
    // graph upload + logits download crossed the simulated link
    assert!(link.bytes_transferred() > 1000, "{}", link.bytes_transferred());
}
