//! Fleet coordinator integration: routing, health, failover.
//!
//! Replicas are full in-process `NdifServer` deployments that self-register
//! with an L3 coordinator; clients talk only to the coordinator. The
//! failover test kills one replica mid-load and asserts every request still
//! completes — the coordinator resubmits interrupted work to a survivor, so
//! a replica crash loses zero accepted requests.

use std::time::{Duration, Instant};

use nnscope::client::remote::{Endpoint, NdifClient};
use nnscope::client::Trace;
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::server::{http, NdifConfig, NdifServer};
use nnscope::tensor::Tensor;

fn coordinator(policy: Policy) -> Coordinator {
    let mut cfg = CoordinatorConfig::local();
    cfg.policy = policy;
    cfg.probe_interval = Duration::from_millis(50);
    cfg.health.degraded_after = Duration::from_millis(400);
    cfg.health.dead_after = Duration::from_secs(2);
    Coordinator::start(cfg).unwrap()
}

fn replica(coord: &Coordinator, latency_s: f64) -> NdifServer {
    let mut cfg = NdifConfig::local(&["tiny-sim"]);
    cfg.coordinator = Some(coord.addr().to_string());
    cfg.heartbeat = Duration::from_millis(50);
    cfg.link_latency_s = latency_s;
    NdifServer::start(cfg).unwrap()
}

fn run_one(client: &NdifClient, v: f32) -> anyhow::Result<()> {
    let tokens = Tensor::new(&[1, 16], vec![v; 16]);
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let s = tr.save(h);
    let res = tr.run_remote(client)?;
    assert_eq!(res.get(s).dims(), &[1, 16, 32]);
    Ok(())
}

#[test]
fn fleet_routes_round_robin_and_discovers() {
    let coord = coordinator(Policy::RoundRobin);
    let r1 = replica(&coord, 0.0);
    let r2 = replica(&coord, 0.0);

    let client = NdifClient::new(coord.addr());
    assert_eq!(client.discover().unwrap(), Endpoint::Fleet);
    assert_eq!(NdifClient::new(r1.addr()).discover().unwrap(), Endpoint::Single);
    assert!(client.health().unwrap());
    assert!(client.models().unwrap().contains(&"tiny-sim".to_string()));

    for i in 0..6 {
        run_one(&client, i as f32).unwrap();
    }
    let (_, c1, f1, _) = r1.metrics("tiny-sim").unwrap();
    let (_, c2, f2, _) = r2.metrics("tiny-sim").unwrap();
    assert_eq!(c1 + c2, 6, "all requests served exactly once");
    assert_eq!(f1 + f2, 0);
    assert!(c1 >= 1 && c2 >= 1, "round-robin did not spread: {c1}/{c2}");

    let status = client.fleet_status().unwrap();
    assert_eq!(status.get("policy").as_str(), Some("round-robin"));
    assert_eq!(status.get("replicas").as_array().unwrap().len(), 2);
}

#[test]
fn latency_aware_prefers_low_latency_replica() {
    let coord = coordinator(Policy::LatencyAware);
    let slow = replica(&coord, 0.250); // a far WAN replica
    let fast = replica(&coord, 0.002); // near replica

    let client = NdifClient::new(coord.addr());
    for i in 0..4 {
        run_one(&client, i as f32).unwrap();
    }
    let (_, c_slow, _, _) = slow.metrics("tiny-sim").unwrap();
    let (_, c_fast, _, _) = fast.metrics("tiny-sim").unwrap();
    assert_eq!(c_fast, 4, "latency-aware sent {c_slow} requests to the far replica");
}

#[test]
fn failover_loses_no_requests() {
    let coord = coordinator(Policy::LeastLoaded);
    let mut r1 = replica(&coord, 0.0);
    let r2 = replica(&coord, 0.0);
    let addr = coord.addr();

    let (n_threads, per) = (4usize, 5usize);
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                for i in 0..per {
                    let tokens = Tensor::new(&[1, 16], vec![(t * per + i) as f32; 16]);
                    let mut tr = Trace::new("tiny-sim", &tokens);
                    let h = tr.output("layer.0");
                    tr.save(h);
                    tr.run_remote(&client).expect("request must survive replica death");
                    std::thread::sleep(Duration::from_millis(30));
                }
                per
            })
        })
        .collect();

    // let some requests land on both replicas, then crash one mid-load
    std::thread::sleep(Duration::from_millis(100));
    r1.kill();

    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, n_threads * per, "zero lost results across the crash");

    // the dead replica must eventually leave the routable set
    let client = NdifClient::new(addr);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let status = client.fleet_status().unwrap();
        let unhealthy = status
            .get("replicas")
            .as_array()
            .unwrap()
            .iter()
            .any(|r| r.get("health").as_str() != Some("alive"));
        if unhealthy {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "coordinator never noticed the dead replica: {status}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // the survivor keeps serving
    run_one(&client, 99.0).unwrap();
    drop(r2);
}

#[test]
fn fleet_management_endpoints() {
    let coord = coordinator(Policy::RoundRobin);
    let caddr = coord.addr();

    // register a replica that isn't actually up
    let (status, body) = http::post(
        caddr,
        "/v1/fleet/register",
        br#"{"addr":"127.0.0.1:1","models":["ghost-model"],"latency_s":0.02}"#,
    )
    .unwrap();
    assert_eq!(status, 200);
    let id = nnscope::json::parse(std::str::from_utf8(&body).unwrap())
        .unwrap()
        .get("id")
        .as_str()
        .unwrap()
        .to_string();

    // it shows up in fleet status with its advertised latency
    let (status, body) = http::get(caddr, "/v1/fleet/status").unwrap();
    assert_eq!(status, 200);
    let j = nnscope::json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let reps = j.get("replicas").as_array().unwrap();
    assert!(reps.iter().any(|r| r.get("id").as_str() == Some(id.as_str())));

    // heartbeats: known id accepted, unknown id → 404 (triggers re-register)
    let hb = format!(r#"{{"id":"{id}","queue_depth":2,"completed":7,"failed":0}}"#);
    let (status, _) = http::post(caddr, "/v1/fleet/heartbeat", hb.as_bytes()).unwrap();
    assert_eq!(status, 200);
    let (status, _) =
        http::post(caddr, "/v1/fleet/heartbeat", br#"{"id":"rep-999"}"#).unwrap();
    assert_eq!(status, 404);

    // a trace to a model hosted only by an unreachable replica never hangs
    // or gets lost: either the monitor already declared the ghost dead
    // (404 at submit) or the request is accepted and cleanly reported
    // failed once failover exhausts its candidates
    let (status, body) = http::post(
        caddr,
        "/v1/trace",
        br#"{"model":"ghost-model","batch":1,"tokens":[],"nodes":[]}"#,
    )
    .unwrap();
    if status == 202 {
        let tid = nnscope::json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap()
            .get("id")
            .as_str()
            .unwrap()
            .to_string();
        let (status, body) =
            http::get(caddr, &format!("/v1/result/{tid}?timeout_ms=30000")).unwrap();
        assert_eq!(status, 500, "{}", String::from_utf8_lossy(&body));
        assert!(String::from_utf8_lossy(&body).contains("error"));
    } else {
        assert_eq!(status, 404, "ghost replica already marked dead");
    }

    // a model nobody hosts is rejected at submit
    let (status, _) = http::post(
        caddr,
        "/v1/trace",
        br#"{"model":"nope","batch":1,"tokens":[],"nodes":[]}"#,
    )
    .unwrap();
    assert_eq!(status, 404);

    // result query parsing mirrors the single server: multi-param queries
    // work, non-numeric timeout_ms is a 400
    let (status, _) = http::get(caddr, "/v1/result/c-999?x=1&timeout_ms=5").unwrap();
    assert_eq!(status, 404);
    let (status, _) = http::get(caddr, "/v1/result/c-999?timeout_ms=abc").unwrap();
    assert_eq!(status, 400);

    // deregistration removes the replica from the registry
    let dr = format!(r#"{{"id":"{id}"}}"#);
    let (status, _) = http::post(caddr, "/v1/fleet/deregister", dr.as_bytes()).unwrap();
    assert_eq!(status, 200);
    let (status, _) = http::post(caddr, "/v1/fleet/deregister", dr.as_bytes()).unwrap();
    assert_eq!(status, 404);
    let (_, body) = http::get(caddr, "/v1/fleet/status").unwrap();
    assert_eq!(
        nnscope::json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap()
            .get("replicas")
            .as_array()
            .unwrap()
            .len(),
        0
    );
}
