//! Bench-regression gate: compare freshly emitted `BENCH_*.json` files
//! against checked-in baselines and fail CI on a >25% regression.
//!
//! Usage (from the repo root, after the quick-mode benches have run):
//!
//! ```sh
//! cargo run --release --bin bench_gate              # check (CI)
//! cargo run --release --bin bench_gate -- --update  # ratchet baselines
//! cargo run --release --bin bench_gate -- --dir benches/baselines
//! ```
//!
//! Every gated metric is higher-is-better; a fresh value below
//! `baseline × (1 - 25%)` fails the job. The checked-in baselines are
//! deliberately **conservative floors** (CI runners vary wildly in core
//! count and clock): they catch order-of-magnitude regressions — a kernel
//! falling back to the naive path, streaming losing its first-token
//! advantage, sessions losing their round-trip advantage — without
//! flaking on hardware noise. Ratchet them upward over time by running
//! `--update` on a representative runner and committing the result.

use nnscope::json::{parse, Json};
use nnscope::util::cli::Args;
use nnscope::util::table::Table;

/// Allowed relative regression before the gate fails.
const MAX_REGRESSION: f64 = 0.25;

/// One gated metric: where it lives and how to pull it out of the JSON.
struct Metric {
    file: &'static str,
    name: &'static str,
    extract: fn(&Json) -> Option<f64>,
}

/// `kernels[]` entry by name → its tokens-equivalent throughput.
fn kernel_throughput(j: &Json, kernel: &str) -> Option<f64> {
    j.get("kernels")
        .as_array()?
        .iter()
        .find(|k| k.get("name").as_str() == Some(kernel))
        .and_then(|k| k.get("tokens_equiv_per_s").as_f64())
}

fn metrics() -> Vec<Metric> {
    vec![
        Metric {
            file: "BENCH_kernels.json",
            name: "matmul tokens_equiv_per_s",
            extract: |j| kernel_throughput(j, "matmul"),
        },
        Metric {
            file: "BENCH_kernels.json",
            name: "softmax tokens_equiv_per_s",
            extract: |j| kernel_throughput(j, "softmax"),
        },
        Metric {
            file: "BENCH_kernels.json",
            name: "broadcast_add tokens_equiv_per_s",
            extract: |j| kernel_throughput(j, "broadcast_add"),
        },
        Metric {
            file: "BENCH_sessions.json",
            name: "sessions speedup_simulated_wan",
            extract: |j| j.get("speedup_simulated_wan").as_f64(),
        },
        Metric {
            file: "BENCH_streaming.json",
            name: "streaming stream_speedup (full/ttft)",
            extract: |j| j.get("stream_speedup").as_f64(),
        },
        Metric {
            file: "BENCH_streaming.json",
            name: "streaming tokens_per_s",
            extract: |j| j.get("tokens_per_s").as_f64(),
        },
        Metric {
            file: "BENCH_graphopt.json",
            name: "graphopt stream_speedup_opt (no-opt/opt)",
            extract: |j| j.get("stream_speedup_opt").as_f64(),
        },
        Metric {
            file: "BENCH_graphopt.json",
            name: "graphopt cotenant_speedup_opt (raw/opt merge)",
            extract: |j| j.get("cotenant_speedup_opt").as_f64(),
        },
        Metric {
            file: "BENCH_plancache.json",
            name: "plancache admission_speedup_hot (cold/hot admission)",
            extract: |j| j.get("admission_speedup_hot").as_f64(),
        },
        Metric {
            file: "BENCH_plancache.json",
            name: "plancache planned_exec_ratio (legacy/planned request wall)",
            extract: |j| j.get("planned_exec_ratio").as_f64(),
        },
        Metric {
            file: "BENCH_obs.json",
            name: "obs on/off throughput ratio",
            extract: |j| j.get("obs_ratio_on_off").as_f64(),
        },
        Metric {
            file: "BENCH_profile.json",
            name: "profile disarmed throughput (rps)",
            extract: |j| j.get("profile_off_rps").as_f64(),
        },
        Metric {
            file: "BENCH_profile.json",
            name: "profile armed throughput (rps)",
            extract: |j| j.get("profiled_rps").as_f64(),
        },
        Metric {
            file: "BENCH_decode.json",
            name: "decode kv_step_speedup (cached step vs recompute)",
            extract: |j| j.get("kv_step_speedup").as_f64(),
        },
        Metric {
            file: "BENCH_decode.json",
            name: "decode step_flatness (early/late per-step cost)",
            extract: |j| j.get("step_flatness").as_f64(),
        },
        Metric {
            file: "BENCH_decode.json",
            name: "decode batch_speedup_8x (batched vs back-to-back)",
            extract: |j| j.get("batch_speedup_8x").as_f64(),
        },
        Metric {
            file: "BENCH_decode.json",
            name: "decode tokens_per_s_8 (aggregate batched)",
            extract: |j| j.get("tokens_per_s_8").as_f64(),
        },
        Metric {
            file: "BENCH_faults.json",
            name: "faults goodput_rps (chaos goodput)",
            extract: |j| j.get("goodput_rps").as_f64(),
        },
        Metric {
            file: "BENCH_faults.json",
            name: "faults success_rate",
            extract: |j| j.get("success_rate").as_f64(),
        },
    ]
}

fn load(path: &std::path::Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    parse(&text).map_err(|e| format!("parse {path:?}: {e}"))
}

fn main() {
    let args = Args::from_env(1);
    let baseline_dir = std::path::PathBuf::from(args.str_or("dir", "benches/baselines"));
    let files = [
        "BENCH_kernels.json",
        "BENCH_sessions.json",
        "BENCH_streaming.json",
        "BENCH_graphopt.json",
        "BENCH_plancache.json",
        "BENCH_obs.json",
        "BENCH_profile.json",
        "BENCH_decode.json",
        "BENCH_faults.json",
    ];

    if args.flag("update") {
        std::fs::create_dir_all(&baseline_dir).expect("create baseline dir");
        for f in files {
            std::fs::copy(f, baseline_dir.join(f))
                .unwrap_or_else(|e| panic!("copy fresh {f} into baselines: {e}"));
            println!("baseline updated: {}", baseline_dir.join(f).display());
        }
        return;
    }

    let mut table = Table::new("bench-regression gate").header(vec![
        "metric", "fresh", "baseline", "floor", "verdict",
    ]);
    let mut failures = Vec::new();
    for m in metrics() {
        let fresh = load(std::path::Path::new(m.file)).and_then(|j| {
            (m.extract)(&j).ok_or_else(|| format!("{} missing in fresh {}", m.name, m.file))
        });
        let base = load(&baseline_dir.join(m.file)).and_then(|j| {
            (m.extract)(&j).ok_or_else(|| {
                format!("{} missing in baseline {} (run --update?)", m.name, m.file)
            })
        });
        match (fresh, base) {
            (Ok(fresh), Ok(base)) => {
                let floor = base * (1.0 - MAX_REGRESSION);
                let ok = fresh >= floor;
                table.row(vec![
                    m.name.to_string(),
                    format!("{fresh:.3}"),
                    format!("{base:.3}"),
                    format!("{floor:.3}"),
                    if ok { "ok".to_string() } else { "REGRESSION".to_string() },
                ]);
                if !ok {
                    failures.push(format!(
                        "{}: {fresh:.3} < floor {floor:.3} (baseline {base:.3})",
                        m.name
                    ));
                }
            }
            (Err(e), _) | (_, Err(e)) => {
                table.row(vec![
                    m.name.to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "MISSING".to_string(),
                ]);
                failures.push(e);
            }
        }
    }
    table.print();
    if failures.is_empty() {
        println!("bench gate: all metrics within {:.0}% of baseline", MAX_REGRESSION * 100.0);
    } else {
        eprintln!("bench gate FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
